//! Property tests on coordinator invariants (routing/batching/state):
//! packing round-trips, batch-order preservation, β monotonicity,
//! constraint semantics, engine equivalence, parallel-sweep determinism
//! and two-phase (profile + overlay) vs fused bit-identity — over
//! randomized requests.

use xrcarbon::carbon::{combine_segments, CiTrace, ScenarioOverlay};
use xrcarbon::dse::batching::evaluate_chunked;
use xrcarbon::dse::sweep::{sweep, sweep_fused, sweep_sequential, SweepConfig, SweepOutcome};
use xrcarbon::dse::ScenarioGrid;
use xrcarbon::matrixform::{
    ConfigRow, EvalRequest, EvalResult, MetricRow, PackedProblem, ProfileRequest, TaskMatrix,
};
use xrcarbon::runtime::{evaluate, evaluate_fused, profile_request, HostEngine, HostEngineFactory};
use xrcarbon::testkit::{forall_cfg, PropConfig, Rng};

fn gen_request(r: &mut Rng) -> EvalRequest {
    let t = r.below(4) + 1;
    let k = r.below(12) + 1;
    let c = r.below(60) + 1;
    let j = r.below(6) + 1;
    let mut tasks = TaskMatrix::new(
        (0..t).map(|i| format!("t{i}")).collect(),
        (0..k).map(|i| format!("k{i}")).collect(),
    );
    for ti in 0..t {
        for ki in 0..k {
            if r.chance(0.6) {
                tasks.set(ti, ki, r.below(30) as f64);
            }
        }
    }
    EvalRequest {
        tasks,
        configs: (0..c)
            .map(|i| ConfigRow {
                name: format!("cfg{i}"),
                f_clk: r.range(1e8, 2e9),
                d_k: (0..k).map(|_| r.range(1e-5, 1e-1)).collect(),
                e_dyn: (0..k).map(|_| r.range(1e-4, 1.0)).collect(),
                leak_w: r.range(0.0, 0.2),
                c_comp: (0..j).map(|_| r.range(0.0, 1000.0)).collect(),
            })
            .collect(),
        online: (0..j).map(|_| if r.chance(0.8) { 1.0 } else { 0.0 }).collect(),
        qos: (0..t)
            .map(|_| if r.chance(0.3) { r.range(0.1, 100.0) } else { f64::INFINITY })
            .collect(),
        ci_use_g_per_j: r.range(1e-5, 1e-3),
        lifetime_s: r.range(1e4, 1e8),
        beta: r.range(0.0, 4.0),
        p_max_w: if r.chance(0.4) { r.range(0.5, 100.0) } else { f64::INFINITY },
    }
}

#[test]
fn prop_pack_preserves_names_and_sizes() {
    forall_cfg(
        PropConfig { cases: 64, seed: 11 },
        gen_request,
        |req| {
            let p = PackedProblem::from_request(req);
            p.c == req.configs.len()
                && p.names.len() == p.c
                && p.names.iter().zip(&req.configs).all(|(n, c)| *n == c.name)
                && p.c_pad >= p.c
        },
    );
}

#[test]
fn prop_metrics_are_nonnegative_and_consistent() {
    forall_cfg(
        PropConfig { cases: 48, seed: 12 },
        gen_request,
        |req| {
            let res = evaluate(&mut HostEngine::new(), req).unwrap();
            (0..res.c).all(|i| {
                let e = res.metric(MetricRow::Energy, i);
                let d = res.metric(MetricRow::Delay, i);
                let c_op = res.metric(MetricRow::COp, i);
                let c_emb = res.metric(MetricRow::CEmb, i);
                let c_total = res.metric(MetricRow::CTotal, i);
                let feas = res.metric(MetricRow::Feasible, i);
                e >= 0.0
                    && d >= 0.0
                    && c_op >= 0.0
                    && c_emb >= 0.0
                    && (c_total - (c_op + c_emb)).abs() <= 1e-5 * c_total.max(1e-12)
                    && (feas == 0.0 || feas == 1.0)
            })
        },
    );
}

#[test]
fn prop_delay_row_sums_task_delays() {
    forall_cfg(
        PropConfig { cases: 48, seed: 13 },
        gen_request,
        |req| {
            let res = evaluate(&mut HostEngine::new(), req).unwrap();
            (0..res.c).all(|i| {
                let sum: f64 = (0..res.t).map(|ti| res.task_delay(i, ti)).sum();
                let d = res.metric(MetricRow::Delay, i);
                (sum - d).abs() <= 1e-4 * d.max(1e-12)
            })
        },
    );
}

#[test]
fn prop_beta_monotone_in_tcdp() {
    forall_cfg(
        PropConfig { cases: 32, seed: 14 },
        |r| (gen_request(r), r.range(0.0, 2.0), r.range(0.0, 2.0)),
        |(req, b1, b2)| {
            let (lo, hi) = if b1 <= b2 { (*b1, *b2) } else { (*b2, *b1) };
            let mut rlo = req.clone();
            rlo.beta = lo;
            let mut rhi = req.clone();
            rhi.beta = hi;
            let mut host = HostEngine::new();
            let a = evaluate(&mut host, &rlo).unwrap();
            let b = evaluate(&mut host, &rhi).unwrap();
            (0..a.c).all(|i| {
                a.metric(MetricRow::Tcdp, i) <= b.metric(MetricRow::Tcdp, i) * (1.0 + 1e-5) + 1e-12
            })
        },
    );
}

#[test]
fn prop_provisioning_mask_shrinks_embodied() {
    forall_cfg(
        PropConfig { cases: 32, seed: 15 },
        gen_request,
        |req| {
            let mut full = req.clone();
            for v in full.online.iter_mut() {
                *v = 1.0;
            }
            let mut masked = full.clone();
            masked.online[0] = 0.0;
            let mut host = HostEngine::new();
            let a = evaluate(&mut host, &full).unwrap();
            let b = evaluate(&mut host, &masked).unwrap();
            (0..a.c)
                .all(|i| b.metric(MetricRow::CEmb, i) <= a.metric(MetricRow::CEmb, i) * (1.0 + 1e-6))
        },
    );
}

#[test]
fn prop_chunked_evaluation_order_stable() {
    // Chunk boundaries must never permute or alter results: compare a
    // direct big-batch evaluation against per-config singleton requests.
    forall_cfg(
        PropConfig { cases: 12, seed: 16 },
        gen_request,
        |req| {
            let mut host = HostEngine::new();
            let whole = evaluate_chunked(&mut host, req).unwrap();
            (0..req.configs.len()).step_by(7.max(req.configs.len() / 3)).all(|i| {
                let single = EvalRequest { configs: vec![req.configs[i].clone()], ..req.clone() };
                let one = evaluate(&mut host, &single).unwrap();
                let (a, b) = (
                    whole.metric(MetricRow::Tcdp, i),
                    one.metric(MetricRow::Tcdp, 0),
                );
                (a - b).abs() <= 1e-6 * a.abs().max(b.abs()).max(1e-12)
            })
        },
    );
}

/// Bitwise equality of two evaluation results (not approximate
/// closeness: the two-phase pipeline must not change a single ULP).
fn results_bit_identical(a: &EvalResult, b: &EvalResult) -> bool {
    a.names == b.names
        && a.metrics.len() == b.metrics.len()
        && a.metrics.iter().zip(&b.metrics).all(|(m, n)| m.to_bits() == n.to_bits())
        && a.d_task.iter().zip(&b.d_task).all(|(m, n)| m.to_bits() == n.to_bits())
}

#[test]
fn prop_two_phase_evaluate_bit_identical_to_fused() {
    // The tentpole invariant at the evaluate level: pack → profile →
    // overlay equals pack → fused execute → unpack, bit for bit.
    forall_cfg(
        PropConfig { cases: 48, seed: 21 },
        gen_request,
        |req| {
            let mut host = HostEngine::new();
            let two = evaluate(&mut host, req).unwrap();
            let fused = evaluate_fused(&mut host, req).unwrap();
            results_bit_identical(&two, &fused)
        },
    );
}

#[test]
fn prop_profile_overlay_reuse_bit_identical_to_fused() {
    // One profile, many scenario overlays: each overlay-composed result
    // must equal the fused engine run of the scenario-applied request.
    forall_cfg(
        PropConfig { cases: 24, seed: 22 },
        |r| (gen_request(r), r.range(0.1, 10.0), r.range(1e4, 1e8), r.range(1e-5, 1e-3)),
        |(req, qos_scale, lifetime, ci)| {
            let mut host = HostEngine::new();
            let neutral = ProfileRequest::from_eval(req).to_eval();
            let prof = profile_request(&mut host, &neutral).unwrap();

            let mut lifetime_sc = req.clone();
            lifetime_sc.lifetime_s = *lifetime;
            let mut mixed_sc = req.clone();
            mixed_sc.ci_use_g_per_j = *ci;
            mixed_sc.beta = 2.0 * mixed_sc.beta;
            for q in mixed_sc.qos.iter_mut() {
                *q *= qos_scale;
            }
            if !mixed_sc.online.is_empty() {
                mixed_sc.online[0] = 0.0;
            }

            [req.clone(), lifetime_sc, mixed_sc].iter().all(|sreq| {
                let two = ScenarioOverlay::from_request(sreq).apply(&prof);
                let fused = evaluate_fused(&mut host, sreq).unwrap();
                results_bit_identical(&two, &fused)
            })
        },
    );
}

/// Bitwise equality of two sweep outcomes (not approximate closeness:
/// the parallel coordinator must not change a single ULP).
fn sweeps_bit_identical(a: &SweepOutcome, b: &SweepOutcome) -> bool {
    a.scenarios.len() == b.scenarios.len()
        && a.scenarios.iter().zip(&b.scenarios).all(|(x, y)| {
            let (rx, ry) = (&x.outcome.result, &y.outcome.result);
            x.label == y.label
                && rx.names == ry.names
                && rx.metrics.len() == ry.metrics.len()
                && rx
                    .metrics
                    .iter()
                    .zip(&ry.metrics)
                    .all(|(m, n)| m.to_bits() == n.to_bits())
                && rx
                    .d_task
                    .iter()
                    .zip(&ry.d_task)
                    .all(|(m, n)| m.to_bits() == n.to_bits())
                && x.outcome.optimal == y.outcome.optimal
                && x.outcome.stats.feasible == y.outcome.stats.feasible
                && x.outcome.stats.best.to_bits() == y.outcome.stats.best.to_bits()
                && x.outcome.stats.mean.to_bits() == y.outcome.stats.mean.to_bits()
                && x.outcome.stats.p5.to_bits() == y.outcome.stats.p5.to_bits()
                && x.outcome.stats.p95.to_bits() == y.outcome.stats.p95.to_bits()
        })
}

#[test]
fn prop_parallel_sweep_bit_identical_to_sequential() {
    // The tentpole determinism invariant: a parallel sweep over randomized
    // requests equals the sequential single-thread run bit-for-bit.
    forall_cfg(
        PropConfig { cases: 10, seed: 18 },
        gen_request,
        |req| {
            let grid = ScenarioGrid::new()
                .with_lifetime("lt=1e5s", 1e5)
                .with_lifetime("lt=1e7s", 1e7)
                .with_beta("b=0.5", 0.5)
                .with_beta("b=2", 2.0)
                .with_qos_scale("qos=x1", 1.0);
            let par = sweep(&HostEngineFactory, req, &grid, &SweepConfig { threads: 4 }).unwrap();
            let seq = sweep_sequential(&mut HostEngine::new(), req, &grid).unwrap();
            sweeps_bit_identical(&par, &seq)
        },
    );
}

#[test]
fn prop_two_phase_sweep_bit_identical_to_fused_sweep() {
    // Coordinator-level: profile-once + overlays equals the PR 1
    // per-scenario fused fan-out over randomized requests.
    forall_cfg(
        PropConfig { cases: 10, seed: 23 },
        gen_request,
        |req| {
            let grid = ScenarioGrid::new()
                .with_lifetime("lt=1e5s", 1e5)
                .with_lifetime("lt=1e7s", 1e7)
                .with_beta("b=0.5", 0.5)
                .with_beta("b=2", 2.0)
                .with_ci("ci=hi", 5e-4);
            let two = sweep(&HostEngineFactory, req, &grid, &SweepConfig { threads: 4 }).unwrap();
            let fused =
                sweep_fused(&HostEngineFactory, req, &grid, &SweepConfig { threads: 4 }).unwrap();
            two.items == fused.items && sweeps_bit_identical(&two, &fused)
        },
    );
}

#[test]
fn parallel_sweep_bit_identical_across_chunk_boundaries() {
    // A space large enough that every scenario splits into several
    // chunks: 2500 configs -> 3 chunks x 4 scenarios = 12 work items.
    let mut rng = Rng::new(0xBEEF);
    let mut req = gen_request(&mut rng);
    let template = req.configs[0].clone();
    req.configs = (0..2500)
        .map(|i| {
            let mut c = template.clone();
            c.name = format!("cfg{i}");
            for d in c.d_k.iter_mut() {
                *d *= 1.0 + (i % 97) as f64 * 1e-3;
            }
            c
        })
        .collect();
    let grid = ScenarioGrid::new()
        .with_lifetime("lt=1e5s", 1e5)
        .with_lifetime("lt=1e7s", 1e7)
        .with_ci("ci=lo", 5e-5)
        .with_ci("ci=hi", 5e-4);
    let par = sweep(&HostEngineFactory, &req, &grid, &SweepConfig { threads: 4 }).unwrap();
    assert_eq!(par.items, 12, "2500 configs should split into 3 chunks per scenario");
    let seq = sweep_sequential(&mut HostEngine::new(), &req, &grid).unwrap();
    assert!(sweeps_bit_identical(&par, &seq));
}

#[test]
fn prop_trace_sweep_bit_identical_to_per_segment_fused() {
    // Trace tentpole invariant: a trace scenario in the sweep equals
    // lowering the trace to per-segment ci overrides, evaluating every
    // segment through the engine, and recombining in the documented f32
    // order — bit for bit — and the two-phase, fused and sequential
    // sweep paths all agree.
    forall_cfg(
        PropConfig { cases: 8, seed: 31 },
        |r| {
            let req = gen_request(r);
            let n = r.below(5) + 1;
            let cis: Vec<f64> = (0..n).map(|_| r.range(20.0, 950.0)).collect();
            (req, cis)
        },
        |(req, cis)| {
            let grid = ScenarioGrid::new()
                .with_lifetime("lt=1e5s", 1e5)
                .with_lifetime("lt=1e7s", 1e7)
                .with_trace("trace=rand", CiTrace::hourly(cis));
            let two = sweep(&HostEngineFactory, req, &grid, &SweepConfig { threads: 4 }).unwrap();
            let fused =
                sweep_fused(&HostEngineFactory, req, &grid, &SweepConfig { threads: 4 }).unwrap();
            let seq = sweep_sequential(&mut HostEngine::new(), req, &grid).unwrap();
            if !(sweeps_bit_identical(&two, &fused) && sweeps_bit_identical(&two, &seq)) {
                return false;
            }
            // Hand-rolled oracle, scenario by scenario.
            let mut host = HostEngine::new();
            grid.scenarios().iter().zip(&two.scenarios).all(|(sc, got)| {
                let lowered = sc.lower();
                let weights: Vec<f32> = lowered.iter().map(|(_, w)| *w).collect();
                let segs: Vec<EvalResult> = lowered
                    .iter()
                    .map(|(s, _)| evaluate_chunked(&mut host, &s.apply(req)).unwrap())
                    .collect();
                let expect = combine_segments(&segs, &weights);
                results_bit_identical(&expect, &got.outcome.result)
            })
        },
    );
}

fn gen_grid(r: &mut Rng) -> ScenarioGrid {
    // Labels reuse a tiny pool plus a per-axis index: unique within one
    // grid, colliding often when two generated grids are crossed.
    let pool = ["p", "q", "r"];
    let mut g = ScenarioGrid::new();
    for i in 0..r.below(3) {
        g = g.with_lifetime(&format!("{}{i}", pool[r.below(3)]), r.range(1e4, 1e8));
    }
    for i in 0..r.below(3) {
        g = g.with_ci(&format!("{}{i}", pool[r.below(3)]), r.range(1e-5, 1e-3));
    }
    for i in 0..r.below(2) {
        g = g.with_beta(&format!("{}{i}", pool[r.below(3)]), r.range(0.1, 3.0));
    }
    for i in 0..r.below(2) {
        g = g.with_trace(&format!("{}{i}", pool[r.below(3)]), CiTrace::flat(r.range(50.0, 900.0)));
    }
    g
}

#[test]
fn prop_cross_preserves_cardinality_and_label_uniqueness() {
    // cross() must multiply cardinalities axis-wise and keep scenario
    // labels unique (report tables and checkpoint digests key on them),
    // even when the two grids reuse the same axis labels.
    forall_cfg(
        PropConfig { cases: 64, seed: 32 },
        |r| (gen_grid(r), gen_grid(r)),
        |(a, b)| {
            let crossed = a.clone().cross(b.clone());
            let expect_card = [
                a.ci.len() + b.ci.len(),
                a.lifetime.len() + b.lifetime.len(),
                a.qos_scale.len() + b.qos_scale.len(),
                a.beta.len() + b.beta.len(),
                a.p_max.len() + b.p_max.len(),
                a.trace.len() + b.trace.len(),
            ]
            .iter()
            .map(|&n| n.max(1))
            .product::<usize>();
            if crossed.cardinality() != expect_card {
                return false;
            }
            // Per-axis labels stay unique and values survive in order.
            for (ours, theirs, merged) in [
                (&a.ci, &b.ci, &crossed.ci),
                (&a.lifetime, &b.lifetime, &crossed.lifetime),
                (&a.qos_scale, &b.qos_scale, &crossed.qos_scale),
                (&a.beta, &b.beta, &crossed.beta),
                (&a.p_max, &b.p_max, &crossed.p_max),
            ] {
                let labels: std::collections::HashSet<&str> =
                    merged.iter().map(|p| p.label.as_str()).collect();
                if labels.len() != merged.len() {
                    return false;
                }
                let values: Vec<f64> = merged.iter().map(|p| p.value).collect();
                let expect: Vec<f64> =
                    ours.iter().chain(theirs.iter()).map(|p| p.value).collect();
                if values != expect {
                    return false;
                }
            }
            let trace_labels: std::collections::HashSet<&str> =
                crossed.trace.iter().map(|p| p.label.as_str()).collect();
            if trace_labels.len() != crossed.trace.len() {
                return false;
            }
            // Scenario labels are unique and match the cardinality.
            let scs = crossed.scenarios();
            let labels: std::collections::HashSet<&str> =
                scs.iter().map(|s| s.label.as_str()).collect();
            scs.len() == expect_card && labels.len() == scs.len()
        },
    );
}

#[test]
fn prop_infeasible_never_selected() {
    forall_cfg(
        PropConfig { cases: 32, seed: 17 },
        gen_request,
        |req| {
            let res = evaluate(&mut HostEngine::new(), req).unwrap();
            match res.argmin_feasible(MetricRow::Tcdp) {
                None => res.row(MetricRow::Feasible).iter().all(|&f| f < 0.5),
                Some(i) => res.metric(MetricRow::Feasible, i) > 0.5,
            }
        },
    );
}
