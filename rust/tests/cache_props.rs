//! Property tests for the persistent profile cache and the resumable
//! sweep/search — the determinism contract of the warm-start layer:
//!
//! * a warm-start sweep over a cached space is **bit-identical** to the
//!   cold run on the host engine and performs **zero** phase-A engine
//!   contractions — through the in-memory LRU (same process) and
//!   through the binary sidecars (fresh process) alike;
//! * binary-envelope round-trips are bit-exact, a lost or corrupted
//!   sidecar falls back to the JSON envelope bit-identically (and is
//!   repaired), and corrupted or stale entries of either format are
//!   rejected and recomputed — results never change, the entries are
//!   never trusted;
//! * the on-disk eviction policy keeps the store under its size budget
//!   without ever evicting the most recent entry;
//! * two cache handles over one directory (one evicting under a byte
//!   budget, one not) interleave store/load/evict traffic without a
//!   single corrupt load: every lookup is a bit-identical hit or a
//!   clean miss/rejection followed by recomputation, and the budget
//!   holds;
//! * a sweep interrupted at *any* chunk and a search interrupted at
//!   *any* generation both resume from their (JSON round-tripped)
//!   checkpoints bit-identically.

use xrcarbon::configfmt::{parse, Json};
use xrcarbon::dse::cache::{CacheConfig, ProfileCache, PROFILE_SCHEMA};
use xrcarbon::dse::search::{SearchCheckpoint, SearchConfig, SearchDriver, SearchOutcome};
use xrcarbon::dse::sweep::{
    sweep, sweep_with_cache, SweepCheckpoint, SweepConfig, SweepDriver, SweepOutcome,
};
use xrcarbon::dse::{DesignPoint, ScenarioGrid, SearchSpace};
use xrcarbon::matrixform::{ConfigRow, EvalRequest, TaskMatrix};
use xrcarbon::runtime::HostEngineFactory;
use xrcarbon::testkit::{forall_cfg, test_dir, PropConfig, Rng};

/// Randomized request: 1–3 tasks, up to 12 kernels, occasionally enough
/// configs to span several profile chunks.
fn gen_request(r: &mut Rng) -> EvalRequest {
    let t = r.below(3) + 1;
    let k = r.below(12) + 1;
    let c = if r.chance(0.15) { 1024 + r.below(600) + 1 } else { r.below(200) + 1 };
    let j = r.below(6) + 1;
    let mut tasks = TaskMatrix::new(
        (0..t).map(|i| format!("t{i}")).collect(),
        (0..k).map(|i| format!("k{i}")).collect(),
    );
    for ti in 0..t {
        for ki in 0..k {
            if r.chance(0.6) {
                tasks.set(ti, ki, r.below(30) as f64);
            }
        }
    }
    EvalRequest {
        tasks,
        configs: (0..c)
            .map(|i| ConfigRow {
                name: format!("cfg{i}"),
                f_clk: r.range(1e8, 2e9),
                d_k: (0..k).map(|_| r.range(1e-5, 1e-1)).collect(),
                e_dyn: (0..k).map(|_| r.range(1e-4, 1.0)).collect(),
                leak_w: r.range(0.0, 0.2),
                c_comp: (0..j).map(|_| r.range(0.0, 1000.0)).collect(),
            })
            .collect(),
        online: (0..j).map(|_| if r.chance(0.8) { 1.0 } else { 0.0 }).collect(),
        qos: (0..t)
            .map(|_| if r.chance(0.3) { r.range(0.1, 100.0) } else { f64::INFINITY })
            .collect(),
        ci_use_g_per_j: r.range(1e-5, 1e-3),
        lifetime_s: r.range(1e4, 1e8),
        beta: r.range(0.0, 4.0),
        p_max_w: if r.chance(0.4) { r.range(0.5, 100.0) } else { f64::INFINITY },
    }
}

/// Randomized scenario grid (1–4 scenarios across two axes).
fn gen_grid(r: &mut Rng) -> ScenarioGrid {
    let mut g = ScenarioGrid::new();
    for i in 0..r.below(2) + 1 {
        g = g.with_lifetime(&format!("lt{i}"), r.range(1e4, 1e8));
    }
    if r.chance(0.5) {
        for i in 0..r.below(2) + 1 {
            g = g.with_beta(&format!("b{i}"), r.range(0.25, 4.0));
        }
    }
    g
}

/// Bit-level equality of two sweep outcomes (metric payloads compared by
/// f64 bits, so NaN-safe and rounding-proof).
fn sweeps_bit_identical(a: &SweepOutcome, b: &SweepOutcome) -> bool {
    let bits = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    a.scenarios.len() == b.scenarios.len()
        && a.scenarios.iter().zip(&b.scenarios).all(|(x, y)| {
            x.label == y.label
                && x.outcome.result.names == y.outcome.result.names
                && bits(&x.outcome.result.metrics) == bits(&y.outcome.result.metrics)
                && bits(&x.outcome.result.d_task) == bits(&y.outcome.result.d_task)
                && x.outcome.optimal == y.outcome.optimal
                && x.outcome.stats.best.to_bits() == y.outcome.stats.best.to_bits()
                && x.outcome.stats.feasible == y.outcome.stats.feasible
        })
}

#[test]
fn prop_warm_sweep_bit_identical_to_cold_with_zero_contractions() {
    forall_cfg(
        PropConfig { cases: 20, seed: 41 },
        |r| (gen_request(r), gen_grid(r)),
        |(req, grid)| {
            let dir = test_dir("cache_props_warm");
            let cache = ProfileCache::open(&dir).unwrap();
            let cfg = SweepConfig::default();

            let nocache = sweep(&HostEngineFactory, req, grid, &cfg).unwrap();
            let cold =
                sweep_with_cache(&HostEngineFactory, req, grid, &cfg, Some(&cache)).unwrap();
            // Warm #1: same process, same cache instance — the memory
            // LRU serves every chunk (no disk read at all).
            let warm =
                sweep_with_cache(&HostEngineFactory, req, grid, &cfg, Some(&cache)).unwrap();
            // Warm #2: a fresh instance models a fresh process — cold
            // memory, every chunk decoded from its binary sidecar.
            let fresh = ProfileCache::open(&dir).unwrap();
            let disk_warm =
                sweep_with_cache(&HostEngineFactory, req, grid, &cfg, Some(&fresh)).unwrap();

            let chunks = cold.profile_chunks;
            let cs = cold.cache.unwrap();
            let ws = warm.cache.unwrap();
            let ds = disk_warm.cache.unwrap();
            let ok = sweeps_bit_identical(&nocache, &cold)
                && sweeps_bit_identical(&cold, &warm)
                && sweeps_bit_identical(&cold, &disk_warm)
                // Cold: every chunk missed and was written back.
                && (cs.hits, cs.misses, cs.writes, cs.rejected) == (0, chunks, chunks, 0)
                // Warm: zero engine contractions — everything a hit,
                // served by the memory layer.
                && (ws.hits, ws.misses, ws.writes) == (chunks, 0, 0)
                && ws.mem_hits == chunks
                && ws.contractions_avoided() == chunks
                // Disk-warm: zero contractions with cold memory too.
                && (ds.hits, ds.mem_hits, ds.misses) == (chunks, 0, 0)
                && chunks >= 1;
            std::fs::remove_dir_all(&dir).ok();
            ok
        },
    );
}

#[test]
fn prop_binary_roundtrip_fallback_and_rejection() {
    forall_cfg(
        PropConfig { cases: 16, seed: 45 },
        |r| (gen_request(r), gen_grid(r), r.below(3)),
        |(req, grid, sidecar_kind)| {
            let dir = test_dir("cache_props_bin");
            let cfg = SweepConfig::default();
            let nomem = CacheConfig { mem_entries: 0, ..CacheConfig::default() };

            // Populate.
            let cache = ProfileCache::open_with(&dir, nomem).unwrap();
            let cold =
                sweep_with_cache(&HostEngineFactory, req, grid, &cfg, Some(&cache)).unwrap();
            let chunks = cold.profile_chunks;

            // (a) Binary round-trip: disk-only warm run is bit-identical.
            let bin_warm =
                sweep_with_cache(&HostEngineFactory, req, grid, &cfg, Some(&cache)).unwrap();
            let bs = bin_warm.cache.unwrap();
            if !(sweeps_bit_identical(&cold, &bin_warm) && (bs.hits, bs.misses) == (chunks, 0)) {
                std::fs::remove_dir_all(&dir).ok();
                return false;
            }

            // (b) Vandalize every sidecar; the JSON fallback must serve
            // bit-identical profiles (hits, not rejections) and repair
            // the sidecars in place.
            let sidecars: Vec<std::path::PathBuf> = std::fs::read_dir(&dir)
                .unwrap()
                .flatten()
                .map(|e| e.path())
                .filter(|p| p.extension().is_some_and(|e| e == "bin"))
                .collect();
            if sidecars.len() != chunks {
                std::fs::remove_dir_all(&dir).ok();
                return false;
            }
            for p in &sidecars {
                match sidecar_kind % 3 {
                    0 => {
                        std::fs::remove_file(p).unwrap();
                    }
                    1 => {
                        let b = std::fs::read(p).unwrap();
                        std::fs::write(p, &b[..b.len() / 2]).unwrap();
                    }
                    _ => {
                        let mut b = std::fs::read(p).unwrap();
                        let mid = b.len() / 2;
                        b[mid] ^= 0x5A;
                        std::fs::write(p, b).unwrap();
                    }
                }
            }
            let fallback =
                sweep_with_cache(&HostEngineFactory, req, grid, &cfg, Some(&cache)).unwrap();
            let fs_ = fallback.cache.unwrap();
            let repaired = sidecars.iter().all(|p| p.exists());
            if !(sweeps_bit_identical(&cold, &fallback)
                && (fs_.hits, fs_.misses, fs_.rejected) == (chunks, 0, 0)
                && repaired)
            {
                std::fs::remove_dir_all(&dir).ok();
                return false;
            }

            // (c) Corrupt sidecar with the JSON envelope gone: rejected
            // and recomputed — identical results, chunks re-written.
            for entry in std::fs::read_dir(&dir).unwrap().flatten() {
                let p = entry.path();
                if p.extension().is_some_and(|e| e == "json") {
                    std::fs::remove_file(&p).unwrap();
                } else if p.extension().is_some_and(|e| e == "bin") {
                    std::fs::write(&p, b"junk sidecar").unwrap();
                }
            }
            let recomputed =
                sweep_with_cache(&HostEngineFactory, req, grid, &cfg, Some(&cache)).unwrap();
            let rs = recomputed.cache.unwrap();
            let ok = sweeps_bit_identical(&cold, &recomputed)
                && (rs.hits, rs.rejected, rs.writes) == (0, chunks, chunks);
            std::fs::remove_dir_all(&dir).ok();
            ok
        },
    );
}

/// Corrupt one on-disk JSON envelope in `kind`-dependent ways.
fn corrupt(path: &std::path::Path, kind: usize) {
    let text = std::fs::read_to_string(path).unwrap();
    match kind % 5 {
        0 => {
            // Stale schema version.
            let mut doc = parse(&text).unwrap();
            if let Json::Obj(o) = &mut doc {
                o.insert("schema".into(), Json::Num((PROFILE_SCHEMA + 7) as f64));
            }
            std::fs::write(path, doc.to_string()).unwrap();
        }
        1 => {
            // Truncation (invalid JSON).
            std::fs::write(path, &text[..text.len() / 3]).unwrap();
        }
        2 => {
            // Arbitrary garbage.
            std::fs::write(path, b"{\"not\": \"an envelope\"}").unwrap();
        }
        3 => {
            // Non-integral bit value inside a buffer.
            let mut doc = parse(&text).unwrap();
            if let Json::Obj(o) = &mut doc {
                if let Some(Json::Obj(p)) = o.get_mut("profile") {
                    if let Some(Json::Arr(xs)) = p.get_mut("energy") {
                        xs[0] = Json::Num(0.5);
                    }
                }
            }
            std::fs::write(path, doc.to_string()).unwrap();
        }
        _ => {
            // Structurally-valid value corruption: a different (valid)
            // integer bit pattern — only the payload digest catches it.
            let mut doc = parse(&text).unwrap();
            if let Json::Obj(o) = &mut doc {
                if let Some(Json::Obj(p)) = o.get_mut("profile") {
                    if let Some(Json::Arr(xs)) = p.get_mut("delay") {
                        xs[0] = Json::Num(987654.0);
                    }
                }
            }
            std::fs::write(path, doc.to_string()).unwrap();
        }
    }
}

#[test]
fn prop_corrupted_or_stale_entries_are_recomputed_never_trusted() {
    forall_cfg(
        PropConfig { cases: 16, seed: 42 },
        |r| (gen_request(r), gen_grid(r), r.below(5)),
        |(req, grid, kind)| {
            let dir = test_dir("cache_props_corrupt");
            // Memory layer off: a same-instance warm hit would mask the
            // on-disk corruption this property is about.
            let nomem = CacheConfig { mem_entries: 0, ..CacheConfig::default() };
            let cache = ProfileCache::open_with(&dir, nomem).unwrap();
            let cfg = SweepConfig::default();
            let cold =
                sweep_with_cache(&HostEngineFactory, req, grid, &cfg, Some(&cache)).unwrap();

            // Vandalize every stored JSON envelope and remove the
            // sidecars (so the binary fast path cannot mask the damage).
            let mut corrupted = 0usize;
            for entry in std::fs::read_dir(&dir).unwrap() {
                let path = entry.unwrap().path();
                if path.extension().is_some_and(|e| e == "json") {
                    corrupt(&path, *kind);
                    corrupted += 1;
                } else if path.extension().is_some_and(|e| e == "bin") {
                    std::fs::remove_file(&path).unwrap();
                }
            }

            // The sweep falls back to recomputation: identical results,
            // every entry rejected, every chunk re-written.
            let recomputed =
                sweep_with_cache(&HostEngineFactory, req, grid, &cfg, Some(&cache)).unwrap();
            let rs = recomputed.cache.unwrap();
            let chunks = cold.profile_chunks;

            // And the re-written cache serves hits again.
            let healed =
                sweep_with_cache(&HostEngineFactory, req, grid, &cfg, Some(&cache)).unwrap();
            let hs = healed.cache.unwrap();

            let ok = corrupted == chunks
                && sweeps_bit_identical(&cold, &recomputed)
                && sweeps_bit_identical(&cold, &healed)
                && (rs.hits, rs.rejected, rs.writes) == (0, chunks, chunks)
                && (hs.hits, hs.misses) == (chunks, 0);
            std::fs::remove_dir_all(&dir).ok();
            ok
        },
    );
}

#[test]
fn prop_eviction_honors_the_size_budget() {
    forall_cfg(
        PropConfig { cases: 8, seed: 44 },
        |r| (r.below(6) + 4, r.below(3) + 2),
        |&(entries, keep)| {
            let dir = test_dir("cache_props_evict");
            // Probe one entry's on-disk footprint.
            let mk = |i: usize| {
                let mut tasks = TaskMatrix::new(vec!["t".into()], vec!["k".into()]);
                tasks.set(0, 0, 2.0);
                EvalRequest {
                    tasks,
                    configs: vec![ConfigRow {
                        name: format!("cfg{i}"),
                        f_clk: 1e9,
                        d_k: vec![1e-3 * (i + 1) as f64],
                        e_dyn: vec![0.01],
                        leak_w: 0.01,
                        c_comp: vec![100.0],
                    }],
                    online: vec![1.0],
                    qos: vec![f64::INFINITY],
                    ci_use_g_per_j: 1e-4,
                    lifetime_s: 1e6,
                    beta: 1.0,
                    p_max_w: f64::INFINITY,
                }
            };
            let grid = ScenarioGrid::new().with_lifetime("lt", 1e6);
            let cfg = SweepConfig::default();

            let probe = ProfileCache::open(&dir).unwrap();
            sweep_with_cache(&HostEngineFactory, &mk(0), &grid, &cfg, Some(&probe)).unwrap();
            let per_entry = probe.disk_bytes();
            std::fs::remove_dir_all(&dir).ok();
            if per_entry == 0 {
                return false;
            }

            // Budget for `keep` entries, then sweep `entries` distinct
            // single-config spaces through one budgeted cache.
            let budget = per_entry * keep as u64 + per_entry / 2;
            let cache = ProfileCache::open_with(
                &dir,
                CacheConfig { budget_bytes: Some(budget), ..CacheConfig::default() },
            )
            .unwrap();
            let mut outs = Vec::new();
            for i in 0..entries {
                outs.push(
                    sweep_with_cache(&HostEngineFactory, &mk(i), &grid, &cfg, Some(&cache))
                        .unwrap(),
                );
            }
            let stats = cache.stats();
            let on_disk = cache.disk_entries();
            // Disk stays under budget (the policy never evicts the most
            // recent entry, so a tiny budget still keeps exactly one);
            // evictions are visible; the newest entry always survives.
            let newest_key = ProfileCache::key_for_request(&mk(entries - 1), "host");
            let ok = cache.disk_bytes() <= budget.max(per_entry * 2)
                && on_disk >= 1
                && on_disk <= keep + 1
                && stats.evictions == entries - on_disk
                && cache.envelope_path(&newest_key).exists()
                // Results were never affected by eviction (each sweep
                // re-derives from scratch or cache, both bit-exact).
                && outs.iter().all(|o| o.scenarios.len() == 1);
            std::fs::remove_dir_all(&dir).ok();
            ok
        },
    );
}

#[test]
fn prop_two_handles_share_a_directory_under_interleaved_eviction() {
    forall_cfg(
        PropConfig { cases: 8, seed: 47 },
        |r| (r.below(5) + 4, r.below(2) + 2, r.below(10)),
        |&(distinct, keep, corrupt_at)| {
            let dir = test_dir("cache_props_two_handles");
            let mk = |i: usize| {
                let mut tasks = TaskMatrix::new(vec!["t".into()], vec!["k".into()]);
                tasks.set(0, 0, 2.0);
                EvalRequest {
                    tasks,
                    configs: vec![ConfigRow {
                        name: format!("cfg{i}"),
                        f_clk: 1e9,
                        d_k: vec![1e-3 * (i + 1) as f64],
                        e_dyn: vec![0.01],
                        leak_w: 0.01,
                        c_comp: vec![100.0],
                    }],
                    online: vec![1.0],
                    qos: vec![f64::INFINITY],
                    ci_use_g_per_j: 1e-4,
                    lifetime_s: 1e6,
                    beta: 1.0,
                    p_max_w: f64::INFINITY,
                }
            };
            let grid = ScenarioGrid::new().with_lifetime("lt", 1e6);
            let cfg = SweepConfig::default();

            // Probe one entry's footprint, then open the two handles:
            // `plain` has no budget, `evicting` keeps ~`keep` entries.
            // Memory LRUs off so every lookup exercises the shared disk.
            let probe = ProfileCache::open(&dir).unwrap();
            sweep_with_cache(&HostEngineFactory, &mk(0), &grid, &cfg, Some(&probe)).unwrap();
            let per_entry = probe.disk_bytes();
            std::fs::remove_dir_all(&dir).ok();
            if per_entry == 0 {
                return false;
            }
            let budget = per_entry * keep as u64 + per_entry / 2;
            let nomem = CacheConfig { mem_entries: 0, ..CacheConfig::default() };
            let plain = ProfileCache::open_with(&dir, nomem).unwrap();
            let evicting = ProfileCache::open_with(
                &dir,
                CacheConfig { budget_bytes: Some(budget), ..nomem },
            )
            .unwrap();

            // References: the uncached truth per request.
            let refs: Vec<SweepOutcome> = (0..distinct)
                .map(|i| sweep(&HostEngineFactory, &mk(i), &grid, &cfg).unwrap())
                .collect();

            // Interleave: `plain` cycles over a fixed key set (loads —
            // often of entries `evicting`'s passes just deleted, which
            // must come back as clean misses and recompute), while
            // `evicting` stores a *fresh* key every round, repeatedly
            // blowing the budget and evicting. Once mid-stream,
            // vandalize one envelope so a rejection lands in the mix.
            let rounds = 2 * distinct;
            for round in 0..rounds {
                if round == corrupt_at % rounds {
                    for entry in std::fs::read_dir(&dir).unwrap().flatten() {
                        let p = entry.path();
                        if p.extension().is_some_and(|e| e == "json") {
                            std::fs::write(&p, b"{\"not\": \"an envelope\"}").unwrap();
                            std::fs::remove_file(p.with_extension("bin")).ok();
                            break;
                        }
                    }
                }
                let a = sweep_with_cache(
                    &HostEngineFactory, &mk(round % distinct), &grid, &cfg, Some(&plain),
                )
                .unwrap();
                if !sweeps_bit_identical(&a, &refs[round % distinct]) {
                    std::fs::remove_dir_all(&dir).ok();
                    return false;
                }
                let b = sweep(&HostEngineFactory, &mk(distinct + round), &grid, &cfg).unwrap();
                let b2 = sweep_with_cache(
                    &HostEngineFactory, &mk(distinct + round), &grid, &cfg, Some(&evicting),
                )
                .unwrap();
                if !sweeps_bit_identical(&b, &b2) {
                    std::fs::remove_dir_all(&dir).ok();
                    return false;
                }
            }

            // Both handles only ever saw clean outcomes (checked above);
            // the books must balance too: every miss/rejection was
            // recomputed and written back, the evicting handle really
            // did evict, and the shared store ends under its budget
            // (modulo the never-evict-the-newest floor).
            let ps = plain.stats();
            let es = evicting.stats();
            let ok = ps.writes == ps.misses + ps.rejected
                && ps.hits + ps.misses + ps.rejected == rounds
                && ps.rejected <= 1
                && (es.hits, es.misses, es.writes) == (0, rounds, rounds)
                && es.evictions > 0
                && evicting.disk_bytes() <= budget.max(per_entry * 2);
            std::fs::remove_dir_all(&dir).ok();
            ok
        },
    );
}

#[test]
fn prop_sweep_interrupted_at_any_chunk_resumes_bit_identically() {
    forall_cfg(
        PropConfig { cases: 10, seed: 46 },
        |r| {
            // Bias toward multi-chunk spaces: the interrupt needs chunks
            // to land between.
            let mut req = gen_request(r);
            if r.chance(0.6) && req.configs.len() < 1100 {
                let target = 1100 + r.below(400);
                let base = req.configs[0].clone();
                while req.configs.len() < target {
                    let mut c = base.clone();
                    let i = req.configs.len();
                    c.name = format!("cfg{i}");
                    c.d_k = c.d_k.iter().map(|d| d * (1.0 + i as f64 * 1e-4)).collect();
                    req.configs.push(c);
                }
            }
            (req, gen_grid(r), r.below(8))
        },
        |(req, grid, interrupt)| {
            let dir = test_dir("cache_props_sweep_resume");
            let cfg = SweepConfig { threads: 1 }; // one chunk per step
            let reference = sweep(&HostEngineFactory, req, grid, &cfg).unwrap();
            let total = reference.profile_chunks;

            // Phase 1: drive `g` steps against a cache, then "crash".
            let g = interrupt % (total + 2);
            let cache = ProfileCache::open(&dir).unwrap();
            let mut d = SweepDriver::new(&HostEngineFactory, req, grid, &cfg);
            for _ in 0..g {
                if d.step(&HostEngineFactory, Some(&cache)).unwrap() {
                    break;
                }
            }
            let ck =
                SweepCheckpoint::from_json_str(&d.checkpoint().to_json_string()).unwrap();
            if ck != d.checkpoint() {
                std::fs::remove_dir_all(&dir).ok();
                return false;
            }

            // Phase 2: a fresh process (fresh cache instance) resumes.
            let cache2 = ProfileCache::open(&dir).unwrap();
            let resumed = SweepDriver::resume(&HostEngineFactory, req, grid, &cfg, &ck)
                .unwrap()
                .run(&HostEngineFactory, Some(&cache2), None)
                .unwrap();
            let stats = resumed.cache.unwrap();
            let done = g.min(total);
            let ok = sweeps_bit_identical(&reference, &resumed)
                && stats.hits == done
                && stats.misses == total - done;
            std::fs::remove_dir_all(&dir).ok();
            ok
        },
    );
}

/// Synthetic smooth landscape (same shape as the search unit tests):
/// enough structure for the guide loop to do real work, in closed form.
fn synth_row(p: &DesignPoint) -> ConfigRow {
    let m = p.num_macs as f64;
    let s = p.sram_bytes as f64 / (1024.0 * 1024.0);
    let f = p.config.freq_hz;
    let stacked = p.config.stacked_sram;
    let d = 40.0 / (m.powf(0.7) * s.powf(0.15)) * (1.0e9 / f);
    let e = 2e-4 * m.powf(0.3) * (f / 1.0e9).powi(2) * if stacked { 0.6 } else { 1.0 }
        + 1e-3 / s.powf(0.1);
    let emb_scale = if stacked { 0.82 } else { 1.0 };
    ConfigRow {
        name: p.label.clone(),
        f_clk: f,
        d_k: vec![d],
        e_dyn: vec![e],
        leak_w: 1e-6 * m + 1e-4 * s,
        c_comp: vec![0.4 * m * emb_scale, 55.0 * s * emb_scale, 90.0],
    }
}

fn synth_space() -> SearchSpace {
    SearchSpace {
        mac: vec![128, 256, 512, 1024, 2048, 4096],
        sram: [0.5f64, 1.0, 2.0, 4.0, 8.0, 16.0]
            .iter()
            .map(|&mb| (mb * 1024.0 * 1024.0) as u64)
            .collect(),
        stacking: vec![false, true],
        clock: vec![0.8e9, 1.2e9],
    }
}

fn synth_base() -> EvalRequest {
    EvalRequest {
        tasks: TaskMatrix::single_task("t", vec!["k".into()], &[1.0]),
        configs: Vec::new(),
        online: vec![1.0, 1.0, 1.0],
        qos: vec![f64::INFINITY],
        ci_use_g_per_j: 1.2e-4,
        lifetime_s: 1e6,
        beta: 1.0,
        p_max_w: f64::INFINITY,
    }
}

fn synth_grid() -> ScenarioGrid {
    ScenarioGrid::new().with_lifetime("lt=2e5s", 2e5).with_lifetime("lt=2e7s", 2e7)
}

/// Bit-level outcome equality (environment fields — engine label,
/// threads — excluded; they are run observables, not search state).
fn outcomes_bit_identical(a: &SearchOutcome, b: &SearchOutcome) -> bool {
    let best = |o: &SearchOutcome| {
        o.best.as_ref().map(|x| (x.scenario, x.index, x.name.clone(), x.tcdp.to_bits()))
    };
    let archive = |o: &SearchOutcome| {
        o.archive
            .iter()
            .map(|p| {
                (
                    p.scenario,
                    p.index,
                    p.name.clone(),
                    p.f1.to_bits(),
                    p.f2.to_bits(),
                    p.tcdp.to_bits(),
                )
            })
            .collect::<Vec<_>>()
    };
    a.evaluations == b.evaluations
        && a.generations == b.generations
        && a.converged == b.converged
        && a.space_size == b.space_size
        && best(a) == best(b)
        && archive(a) == archive(b)
}

#[test]
fn prop_search_interrupted_at_any_generation_resumes_bit_identically() {
    let space = synth_space();
    let base = synth_base();
    let grid = synth_grid();
    forall_cfg(
        PropConfig { cases: 12, seed: 43 },
        |r| (r.below(1 << 30) as u64, r.below(64)),
        |&(seed, interrupt)| {
            let cfg = SearchConfig {
                seed,
                init_points_per_axis: 3,
                ..SearchConfig::default()
            };

            // Uninterrupted reference, counting loop iterations.
            let mut full = SearchDriver::new(&space, &cfg);
            let mut steps = 0usize;
            while !full
                .step(&HostEngineFactory, &space, &synth_row, &base, &grid, None)
                .unwrap()
            {
                steps += 1;
            }
            let reference = full.outcome(&space, &grid);

            // Interrupt after `g` iterations (anywhere from "before the
            // first generation" to "already finished"), round-trip the
            // checkpoint through its JSON envelope, resume, finish.
            let g = interrupt % (steps + 2);
            let mut partial = SearchDriver::new(&space, &cfg);
            for _ in 0..g {
                if partial
                    .step(&HostEngineFactory, &space, &synth_row, &base, &grid, None)
                    .unwrap()
                {
                    break;
                }
            }
            let ck =
                SearchCheckpoint::from_json_str(&partial.checkpoint().to_json_string()).unwrap();
            if ck != partial.checkpoint() {
                return false;
            }
            let resumed = SearchDriver::resume(&space, &cfg, &ck)
                .unwrap()
                .run(&HostEngineFactory, &space, &synth_row, &base, &grid)
                .unwrap();
            outcomes_bit_identical(&reference, &resumed)
        },
    );
}
