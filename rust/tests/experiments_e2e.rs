//! Integration: every experiment regenerates end-to-end and the paper's
//! headline quantitative claims hold. When the PJRT AOT artifacts are
//! present the engine-backed experiments run on the real AOT path; when
//! they are absent the host engine mirror is used as a fallback instead
//! of skipping the test outright (the claims hold on either engine —
//! pjrt-vs-host stays within a 1e-5 envelope by construction).
//!
//! The engine-free figures (fig2/3/4/9/12/14/table5) intentionally
//! re-assert the same paper-claim thresholds their module unit tests
//! lock: this file is the single place that walks *every* experiment's
//! public entry the way the CLI does, so a threshold retune must touch
//! the module test and the claim here together, by design.

use xrcarbon::accel::Workload;
use xrcarbon::dse::search::exhaustive_front;
use xrcarbon::experiments::common::Ctx;
use xrcarbon::experiments::{
    fig01_metric_comparison, fig02_retrospective, fig03_fleet_categories, fig04_power_embodied,
    fig07_dse_clusters, fig08_tcdp_vs_edp, fig09_accelerators, fig10_lifetime_crossover,
    fig11_provisioning_savings, fig12_tlp_breakdown, fig13_core_configs, fig14_replacement,
    fig15_stacking, fig16_stacking_kernels, search_fig7, table5_vr_soc,
};
use xrcarbon::runtime::{auto_factory, EngineFactory};
use xrcarbon::soc::VrSoc;
use xrcarbon::workloads::{Cluster, FleetConfig};

/// PJRT when artifacts are built, host fallback otherwise — the
/// experiment runs either way.
fn engine_ctx() -> Ctx {
    let ctx = Ctx::auto();
    if ctx.backend != "pjrt" {
        eprintln!("note: PJRT artifacts absent — running on the host-engine fallback");
    }
    ctx
}

/// Factory counterpart of [`engine_ctx`] for the sweep/search paths.
fn engine_factory() -> Box<dyn EngineFactory> {
    auto_factory(xrcarbon::experiments::common::ARTIFACTS_DIR)
}

/// Small fleet config so the trace-driven figures stay fast in CI.
fn fleet_cfg() -> FleetConfig {
    FleetConfig { devices: 150, days: 10, ..Default::default() }
}

#[test]
fn fig7_headline_claims() {
    let mut ctx = engine_ctx();
    let f = fig07_dse_clusters::run(ctx.engine.as_mut()).unwrap();
    assert_eq!(f.panels.len(), 3);

    // Specialization gain at 98% embodied (paper: 5-AI 7.3x vs All).
    let p98 = &f.panels[0];
    let ai5 = p98.cells.iter().find(|c| c.cluster == Cluster::Ai5).unwrap();
    let gain_98 = 1.0 / ai5.best;
    assert!(gain_98 > 1.8, "5-AI specialization gain @98% = {gain_98:.2}x");

    // Gain persists but diminishes as operational carbon grows
    // (paper: 7.3x -> 2.9x from 98% to 25%).
    let p25 = &f.panels[2];
    let ai5_25 = p25.cells.iter().find(|c| c.cluster == Cluster::Ai5).unwrap();
    let gain_25 = 1.0 / ai5_25.best;
    assert!(gain_25 > 1.2, "5-AI gain @25% = {gain_25:.2}x");

    // Best-vs-average headroom (paper: up to ~10x).
    assert!(
        ai5.mean / ai5.best > 2.0,
        "best-vs-average @98% = {:.2}",
        ai5.mean / ai5.best
    );

    // Every scenario/cluster found a feasible optimum.
    for p in &f.panels {
        for c in &p.cells {
            assert!(c.best.is_finite() && c.best > 0.0);
            assert!(c.p5 <= c.p95);
        }
    }
}

#[test]
fn fig8_and_fig1_claims() {
    let mut ctx = engine_ctx();
    let f8 = fig08_tcdp_vs_edp::run(ctx.engine.as_mut()).unwrap();
    assert!(f8.rows.iter().all(|r| r.gain >= 1.0));
    assert!(f8.rows.iter().any(|r| r.gain > 1.3));

    let f1 = fig01_metric_comparison::run(&mut ctx).unwrap();
    let optimal = |metric: &str| {
        let (_, _, idx) = f1.metrics.iter().find(|(m, _, _)| *m == metric).unwrap();
        f1.names[*idx].clone()
    };
    assert_eq!(optimal("EDP"), "A-2");
    assert_eq!(optimal("CDP"), "A-2");
    assert_eq!(optimal("CEP"), "A-1");
}

#[test]
fn fig2_retrospective_claims() {
    // Paper Fig 2: the EDP winner is the newest part on both panels,
    // while the carbon-aware metrics move the star to older/leaner parts.
    let cpus = fig02_retrospective::run_cpus();
    let star = |p: &fig02_retrospective::Fig02Panel, metric: &str| {
        let (_, _, idx) = p.metrics.iter().find(|(m, _, _)| *m == metric).unwrap();
        p.names[*idx].clone()
    };
    assert_eq!(star(&cpus, "EDP"), "EPYC-7702");
    assert_eq!(star(&cpus, "CDP"), "E5-2680");
    assert_eq!(star(&cpus, "CEP"), "E-2234");

    let socs = fig02_retrospective::run_socs();
    assert_eq!(star(&socs, "EDP"), "Snapdragon-865");
    assert_eq!(star(&socs, "CDP"), "Snapdragon-835");
    assert_eq!(star(&socs, "CEP"), "Snapdragon-855");
    assert_eq!(socs.table.len(), 3);
}

#[test]
fn fig3_fleet_categorization_claims() {
    // Paper §2.1: the top-10 apps dominate fleet compute cycles and
    // gaming leads the category split.
    let f = fig03_fleet_categories::run(&fleet_cfg());
    assert!(
        f.summary.top10_cycle_share > 0.82,
        "top-10 share = {}",
        f.summary.top10_cycle_share
    );
    let [g, sg, ..] = f.summary.category_share;
    assert!(g > sg, "gaming {g} must lead social {sg}");
    assert_eq!(f.table.len(), 5);
}

#[test]
fn fig4_unused_embodied_claims() {
    // Paper §1/§2.2: "over 60%" of CPU+GPU embodied carbon sits unused;
    // per-app power stays well under TDP.
    let f = fig04_power_embodied::run(&fleet_cfg(), &VrSoc::default());
    assert_eq!(f.rows.len(), 10);
    assert!(f.mean_unused_share > 0.5, "mean unused share = {}", f.mean_unused_share);
    for r in &f.rows {
        let (p5, mean, p95) = r.power_frac;
        assert!(p5 <= mean && mean <= p95);
        assert!(p95 <= 1.0, "{}: p95 power above TDP", r.name);
        assert!(r.utilized_g > 0.0 && r.unused_g > 0.0);
    }
}

#[test]
fn fig9_accelerator_claims() {
    // Paper Fig 9: A-2 is the fastest by ~4-5.5x; A-1 carries the least
    // embodied carbon, A-2 the most.
    let f = fig09_accelerators::run();
    let row = |name: &str| f.rows.iter().find(|r| r.name == name).unwrap();
    let (a1, a2, a3, a4) = (row("A-1"), row("A-2"), row("A-3"), row("A-4"));
    assert!(a2.total_delay_s < a1.total_delay_s.min(a3.total_delay_s).min(a4.total_delay_s));
    let r12 = a1.total_delay_s / a2.total_delay_s;
    assert!((3.0..9.0).contains(&r12), "A-1/A-2 delay ratio = {r12}");
    assert!(a2.embodied_g > a3.embodied_g && a3.embodied_g > a4.embodied_g);
    assert!(a4.embodied_g > a1.embodied_g);
    let e21 = a2.embodied_g / a1.embodied_g;
    assert!((2.5..6.5).contains(&e21), "A-2/A-1 embodied ratio = {e21}");
}

#[test]
fn fig12_tlp_claims() {
    // Paper §5.4: per-app TLP between 3.52 and 4.15, averaging ~3.9,
    // and the synthetic fleet observation tracks the model.
    let f = fig12_tlp_breakdown::run(&fleet_cfg());
    assert_eq!(f.rows.len(), 4);
    assert!((3.7..4.1).contains(&f.avg_tlp), "avg TLP = {}", f.avg_tlp);
    for (name, tlp, observed, frac) in &f.rows {
        assert!((3.4..4.3).contains(tlp), "{name}: TLP = {tlp}");
        assert!((tlp - observed).abs() < 0.4, "{name}: model {tlp} vs fleet {observed}");
        let total: f64 = frac.iter().sum();
        assert!((total - 1.0).abs() < 1e-5, "{name}: fractions sum to {total}");
    }
}

#[test]
fn fig14_replacement_claims() {
    // Paper Fig 14: heavier daily use shortens the carbon-optimal
    // replacement period (1h -> 5y, 12h -> <=3y), with substantial
    // savings between the optimal and worst periods.
    let f = fig14_replacement::run();
    let opts: Vec<f64> = f.panels.iter().map(|p| p.optimal_years).collect();
    assert_eq!(opts[0], 5.0, "1h/day optimum");
    assert!(opts[2] <= 3.0, "12h/day optimum = {}", opts[2]);
    assert!(opts[0] >= opts[1] && opts[1] >= opts[2]);
    assert!(f.panels[0].savings_vs_worst > 0.3);
    for p in &f.panels {
        assert_eq!(p.sweep.len(), fig14_replacement::CANDIDATES.len());
    }
}

#[test]
fn table5_calibration_claims() {
    // Paper Table 5: the embodied model reproduces the published VR SoC
    // component carbon (gold cores 895.89 g, silver 447.94 g).
    let t = table5_vr_soc::run();
    assert!((t.gold_g - 895.89).abs() < 0.5, "gold = {}", t.gold_g);
    assert!((t.silver_g - 447.94).abs() < 0.3, "silver = {}", t.silver_g);
    assert_eq!(t.table.len(), 6);
}

#[test]
fn fig10_crossover_claims() {
    let mut ctx = engine_ctx();
    let f = fig10_lifetime_crossover::run(
        ctx.engine.as_mut(),
        &fig10_lifetime_crossover::default_axis(),
    )
    .unwrap();
    let series = |name: &str| &f.series.iter().find(|(n, _)| n == name).unwrap().1;
    let (a1, a3) = (series("A-1"), series("A-3"));
    assert!(a1[0] > a3[0], "A-1 wins at 1e3");
    let last = f.n_inf.len() - 1;
    assert!(a3[last] > a1[last], "A-3 wins at 1e8");
}

#[test]
fn provisioning_figures_claims() {
    let mut ctx = engine_ctx();
    let f13 = fig13_core_configs::run(ctx.engine.as_mut()).unwrap();
    let optimal =
        |name: &str| f13.rows.iter().find(|r| r.workload == name).unwrap().optimal_cores;
    assert_eq!(optimal("G-2"), 4);
    assert_eq!(optimal("B-1 & S-1"), 7);
    assert_eq!(optimal("SG-1"), 6);
    assert_eq!(optimal("All Apps"), 5);

    let f11 = fig11_provisioning_savings::run(ctx.engine.as_mut()).unwrap();
    assert!(f11.mean_embodied_saving > 0.2);
    assert!(f11.mean_total_saving > 0.03);
}

#[test]
fn stacking_figures_claims() {
    let mut ctx = engine_ctx();
    let f15 = fig15_stacking::run(ctx.engine.as_mut(), Workload::Sr512).unwrap();
    let best_op = f15.panels[1].gains.iter().map(|(_, g)| *g).fold(0.0f64, f64::max);
    assert!(best_op > 1.8, "SR-512 @6% best gain = {best_op:.2}x");

    let f16 = fig16_stacking_kernels::run(ctx.engine.as_mut()).unwrap();
    // Operational-dominant: every kernel's optimum is a stacked design.
    for c in f16.cells.iter().filter(|c| c.ratio == 0.06) {
        assert!(c.optimal.starts_with("3D_"), "{}: {}", c.kernel.label(), c.optimal);
    }
    // Embodied-dominant: at least one kernel keeps the 2D baseline.
    assert!(f16
        .cells
        .iter()
        .filter(|c| c.ratio == 0.98)
        .any(|c| c.optimal.starts_with("2D")));
}

#[test]
fn search_anchor_finds_fig7_optimum_within_budget() {
    // Acceptance: on the 121-point Fig 7 space the adaptive search finds
    // the exhaustive feasible-tCDP optimum exactly (bit-equal tCDP, same
    // design, same scenario) while evaluating <= 60% of the grid.
    use xrcarbon::dse::search::SearchConfig;
    let factory = engine_factory();
    let f = search_fig7::run(factory.as_ref(), Cluster::Ai5, &SearchConfig::default()).unwrap();
    let (esi, eci, etcdp) = f.exhaustive.best().expect("exhaustive optimum");
    let best = f.outcome.best.as_ref().expect("search optimum");
    assert_eq!(best.name, f.exhaustive.scenarios[esi].outcome.result.names[eci]);
    assert_eq!(best.scenario_label, f.exhaustive.scenarios[esi].label);
    if f.outcome.engine == "host" {
        // Host per-config arithmetic is batch-position-independent.
        assert_eq!(best.tcdp.to_bits(), etcdp.to_bits(), "search tCDP must be bit-exact");
    } else {
        // PJRT may fuse differently across batch compositions; stay
        // within the established pjrt-vs-host envelope.
        assert!((best.tcdp - etcdp).abs() <= 1e-5 * etcdp.abs());
    }
    assert!(f.outcome.converged);
    assert!(
        f.outcome.evaluations * 10 <= f.outcome.space_size * 6,
        "evaluated {}/{} (> 60%)",
        f.outcome.evaluations,
        f.outcome.space_size
    );
    // The archive never claims a point off the exhaustive Pareto front
    // (exact set comparison needs the host engine's bit-stable batches).
    if f.outcome.engine == "host" {
        let front = exhaustive_front(&f.exhaustive);
        for a in &f.outcome.archive {
            assert!(front.contains(&(a.scenario, a.name.clone())), "({}, {})", a.scenario, a.name);
        }
    }
}

#[test]
fn search_expanded_space_converges_deterministically() {
    // Acceptance: on the ~10k-point 2-D/3-D space the search converges
    // to a Pareto archive deterministically for a fixed seed —
    // bit-identical across runs and thread counts — evaluating only a
    // small fraction of the space, and the §5.6 stacking win emerges:
    // the optimum is a 3-D stacked design.
    use xrcarbon::dse::search::SearchConfig;
    let factory = engine_factory();
    let run = |threads: usize| {
        search_fig7::run_expanded(
            factory.as_ref(),
            Cluster::Xr5,
            &SearchConfig { threads, ..SearchConfig::default() },
        )
        .unwrap()
    };
    let a = run(1);
    assert!(a.outcome.converged);
    assert_eq!(a.outcome.space_size, 10_332);
    assert!(
        a.outcome.evaluations * 100 <= a.outcome.space_size * 15,
        "evaluated {}/{} (> 15%)",
        a.outcome.evaluations,
        a.outcome.space_size
    );
    let best = a.outcome.best.as_ref().expect("feasible optimum");
    assert!(best.name.starts_with("3D_"), "stacking win missing: optimum = {}", best.name);
    assert!(!a.outcome.archive.is_empty());

    // Bit-identical across a repeat run and a different thread count.
    let b = run(1);
    let c = run(4);
    for other in [&b, &c] {
        assert_eq!(a.outcome.evaluations, other.outcome.evaluations);
        assert_eq!(a.outcome.generations, other.outcome.generations);
        assert_eq!(a.outcome.archive, other.outcome.archive);
        let (x, y) = (a.outcome.best.as_ref().unwrap(), other.outcome.best.as_ref().unwrap());
        assert_eq!(x.name, y.name);
        assert_eq!(x.scenario, y.scenario);
        assert_eq!(x.tcdp.to_bits(), y.tcdp.to_bits());
    }
}
