//! Integration: every experiment regenerates through the PJRT engine
//! (when artifacts are present) and the paper's headline quantitative
//! claims hold on the real AOT path, not just the host mirror.

use xrcarbon::accel::Workload;
use xrcarbon::experiments::common::Ctx;
use xrcarbon::experiments::{
    fig01_metric_comparison, fig07_dse_clusters, fig08_tcdp_vs_edp, fig10_lifetime_crossover,
    fig11_provisioning_savings, fig13_core_configs, fig15_stacking, fig16_stacking_kernels,
};
use xrcarbon::workloads::Cluster;

fn pjrt_ctx() -> Option<Ctx> {
    let ctx = Ctx::auto();
    if ctx.backend != "pjrt" {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(ctx)
}

#[test]
fn fig7_headline_claims_on_pjrt() {
    let Some(mut ctx) = pjrt_ctx() else { return };
    let f = fig07_dse_clusters::run(ctx.engine.as_mut()).unwrap();
    assert_eq!(f.panels.len(), 3);

    // Specialization gain at 98% embodied (paper: 5-AI 7.3x vs All).
    let p98 = &f.panels[0];
    let ai5 = p98.cells.iter().find(|c| c.cluster == Cluster::Ai5).unwrap();
    let gain_98 = 1.0 / ai5.best;
    assert!(gain_98 > 1.8, "5-AI specialization gain @98% = {gain_98:.2}x");

    // Gain persists but diminishes as operational carbon grows
    // (paper: 7.3x -> 2.9x from 98% to 25%).
    let p25 = &f.panels[2];
    let ai5_25 = p25.cells.iter().find(|c| c.cluster == Cluster::Ai5).unwrap();
    let gain_25 = 1.0 / ai5_25.best;
    assert!(gain_25 > 1.2, "5-AI gain @25% = {gain_25:.2}x");

    // Best-vs-average headroom (paper: up to ~10x).
    assert!(
        ai5.mean / ai5.best > 2.0,
        "best-vs-average @98% = {:.2}",
        ai5.mean / ai5.best
    );

    // Every scenario/cluster found a feasible optimum.
    for p in &f.panels {
        for c in &p.cells {
            assert!(c.best.is_finite() && c.best > 0.0);
            assert!(c.p5 <= c.p95);
        }
    }
}

#[test]
fn fig8_and_fig1_on_pjrt() {
    let Some(mut ctx) = pjrt_ctx() else { return };
    let f8 = fig08_tcdp_vs_edp::run(ctx.engine.as_mut()).unwrap();
    assert!(f8.rows.iter().all(|r| r.gain >= 1.0));
    assert!(f8.rows.iter().any(|r| r.gain > 1.3));

    let f1 = fig01_metric_comparison::run(&mut ctx).unwrap();
    let optimal = |metric: &str| {
        let (_, _, idx) = f1.metrics.iter().find(|(m, _, _)| *m == metric).unwrap();
        f1.names[*idx].clone()
    };
    assert_eq!(optimal("EDP"), "A-2");
    assert_eq!(optimal("CDP"), "A-2");
    assert_eq!(optimal("CEP"), "A-1");
}

#[test]
fn fig10_crossovers_on_pjrt() {
    let Some(mut ctx) = pjrt_ctx() else { return };
    let f = fig10_lifetime_crossover::run(
        ctx.engine.as_mut(),
        &fig10_lifetime_crossover::default_axis(),
    )
    .unwrap();
    let series = |name: &str| &f.series.iter().find(|(n, _)| n == name).unwrap().1;
    let (a1, a3) = (series("A-1"), series("A-3"));
    assert!(a1[0] > a3[0], "A-1 wins at 1e3");
    let last = f.n_inf.len() - 1;
    assert!(a3[last] > a1[last], "A-3 wins at 1e8");
}

#[test]
fn provisioning_figures_on_pjrt() {
    let Some(mut ctx) = pjrt_ctx() else { return };
    let f13 = fig13_core_configs::run(ctx.engine.as_mut()).unwrap();
    let optimal =
        |name: &str| f13.rows.iter().find(|r| r.workload == name).unwrap().optimal_cores;
    assert_eq!(optimal("G-2"), 4);
    assert_eq!(optimal("B-1 & S-1"), 7);
    assert_eq!(optimal("SG-1"), 6);
    assert_eq!(optimal("All Apps"), 5);

    let f11 = fig11_provisioning_savings::run(ctx.engine.as_mut()).unwrap();
    assert!(f11.mean_embodied_saving > 0.2);
    assert!(f11.mean_total_saving > 0.03);
}

#[test]
fn stacking_figures_on_pjrt() {
    let Some(mut ctx) = pjrt_ctx() else { return };
    let f15 = fig15_stacking::run(ctx.engine.as_mut(), Workload::Sr512).unwrap();
    let best_op = f15.panels[1].gains.iter().map(|(_, g)| *g).fold(0.0f64, f64::max);
    assert!(best_op > 1.8, "SR-512 @6% best gain = {best_op:.2}x");

    let f16 = fig16_stacking_kernels::run(ctx.engine.as_mut()).unwrap();
    // Operational-dominant: every kernel's optimum is a stacked design.
    for c in f16.cells.iter().filter(|c| c.ratio == 0.06) {
        assert!(c.optimal.starts_with("3D_"), "{}: {}", c.kernel.label(), c.optimal);
    }
    // Embodied-dominant: at least one kernel keeps the 2D baseline.
    assert!(f16
        .cells
        .iter()
        .filter(|c| c.ratio == 0.98)
        .any(|c| c.optimal.starts_with("2D")));
}
