//! Oracle property tests for `dse::search`: on the 121-point Fig 7 grid
//! (profiled once on the real simulator, then reused), the adaptive
//! search must converge to the same feasible tCDP argmin as the
//! exhaustive `dse::sweep` path under randomized scenario grids, its
//! archive must be a subset of the exhaustive pooled Pareto front, and
//! the outcome must be bit-identical across runs and thread counts.

use std::sync::OnceLock;

use xrcarbon::carbon::{FabGrid, UseGrid};
use xrcarbon::dse::search::{exhaustive_front, search, ReplayEvaluator, SearchConfig};
use xrcarbon::dse::sweep::{sweep, SweepConfig};
use xrcarbon::dse::{
    design_grid, lifetime_for_ratio, profile_configs, profiles_to_rows, ScenarioGrid, SearchSpace,
};
use xrcarbon::matrixform::{ConfigRow, EvalRequest, TaskMatrix};
use xrcarbon::runtime::HostEngineFactory;
use xrcarbon::testkit::{forall_cfg, PropConfig, Rng};
use xrcarbon::workloads::{cluster_workloads, Cluster};

/// The 121-point grid profiled once on the 5-AI cluster.
fn grid_rows() -> &'static (Vec<ConfigRow>, TaskMatrix) {
    static ROWS: OnceLock<(Vec<ConfigRow>, TaskMatrix)> = OnceLock::new();
    ROWS.get_or_init(|| {
        let grid = design_grid();
        let configs: Vec<_> = grid.iter().map(|p| p.config.clone()).collect();
        let workloads = cluster_workloads(Cluster::Ai5);
        let profiles = profile_configs(&configs, &workloads);
        let rows = profiles_to_rows(&configs, &profiles, FabGrid::Coal);
        let kernels: Vec<String> = workloads.iter().map(|w| w.label().to_string()).collect();
        let calls = vec![1.0; kernels.len()];
        let tasks = TaskMatrix::single_task("suite", kernels, &calls);
        (rows, tasks)
    })
}

fn base_request(tasks: &TaskMatrix) -> EvalRequest {
    EvalRequest {
        tasks: tasks.clone(),
        configs: Vec::new(),
        online: vec![1.0, 1.0, 1.0],
        qos: vec![f64::INFINITY],
        ci_use_g_per_j: UseGrid::WorldAverage.g_per_joule(),
        lifetime_s: 1.0,
        beta: 1.0,
        p_max_w: f64::INFINITY,
    }
}

/// Randomized scenario grid: 1–3 ratio-calibrated lifetimes, optionally
/// crossed with CI and β axes (up to 12 scenarios).
fn gen_grid(r: &mut Rng, rows: &[ConfigRow], tasks: &TaskMatrix) -> ScenarioGrid {
    let ci_world = UseGrid::WorldAverage.g_per_joule();
    let mut g = ScenarioGrid::new();
    for i in 0..r.below(3) + 1 {
        let ratio = r.range(0.05, 0.95);
        g = g.with_lifetime(
            &format!("lt{i}"),
            lifetime_for_ratio(rows, tasks, ratio, ci_world),
        );
    }
    if r.chance(0.5) {
        for i in 0..r.below(2) + 1 {
            g = g.with_ci(&format!("ci{i}"), ci_world * r.range(0.2, 3.2));
        }
    }
    if r.chance(0.5) {
        for i in 0..r.below(2) + 1 {
            g = g.with_beta(&format!("b{i}"), r.range(0.25, 4.0));
        }
    }
    g
}

#[test]
fn prop_search_argmin_matches_exhaustive_sweep() {
    let (rows, tasks) = grid_rows();
    let evaluator = ReplayEvaluator::new(rows);
    let base = base_request(tasks);
    let space = SearchSpace::fig7_grid();
    forall_cfg(
        PropConfig { cases: 12, seed: 31 },
        |r| (gen_grid(r, rows, tasks), r.below(1 << 30) as u64),
        |(grid, seed)| {
            let full = EvalRequest { configs: rows.clone(), ..base.clone() };
            let ex = sweep(&HostEngineFactory, &full, grid, &SweepConfig::default()).unwrap();
            let (esi, eci, etcdp) = ex.best().expect("feasible exhaustive optimum");

            let cfg = SearchConfig { seed: *seed, ..SearchConfig::default() };
            let out =
                search(&HostEngineFactory, &space, &evaluator, &base, grid, &cfg).unwrap();
            let best = out.best.expect("feasible search optimum");
            out.converged
                && best.name == ex.scenarios[esi].outcome.result.names[eci]
                && best.scenario == esi
                && best.tcdp.to_bits() == etcdp.to_bits()
        },
    );
}

#[test]
fn prop_search_archive_subset_of_exhaustive_front() {
    let (rows, tasks) = grid_rows();
    let evaluator = ReplayEvaluator::new(rows);
    let base = base_request(tasks);
    let space = SearchSpace::fig7_grid();
    forall_cfg(
        PropConfig { cases: 10, seed: 32 },
        |r| (gen_grid(r, rows, tasks), r.below(1 << 30) as u64),
        |(grid, seed)| {
            let full = EvalRequest { configs: rows.clone(), ..base.clone() };
            let ex = sweep(&HostEngineFactory, &full, grid, &SweepConfig::default()).unwrap();
            let front = exhaustive_front(&ex);

            let cfg = SearchConfig { seed: *seed, ..SearchConfig::default() };
            let out =
                search(&HostEngineFactory, &space, &evaluator, &base, grid, &cfg).unwrap();
            !out.archive.is_empty()
                && out
                    .archive
                    .iter()
                    .all(|a| front.contains(&(a.scenario, a.name.clone())))
        },
    );
}

#[test]
fn prop_search_bit_identical_across_thread_counts() {
    let (rows, tasks) = grid_rows();
    let evaluator = ReplayEvaluator::new(rows);
    let base = base_request(tasks);
    let space = SearchSpace::fig7_grid();
    forall_cfg(
        PropConfig { cases: 8, seed: 33 },
        |r| (gen_grid(r, rows, tasks), r.below(1 << 30) as u64),
        |(grid, seed)| {
            let run = |threads: usize| {
                let cfg = SearchConfig { seed: *seed, threads, ..SearchConfig::default() };
                search(&HostEngineFactory, &space, &evaluator, &base, grid, &cfg).unwrap()
            };
            let a = run(1);
            let b = run(4);
            let best_bits = |o: &xrcarbon::dse::search::SearchOutcome| {
                o.best.as_ref().map(|x| (x.scenario, x.name.clone(), x.tcdp.to_bits()))
            };
            a.evaluations == b.evaluations
                && a.generations == b.generations
                && a.converged == b.converged
                && best_bits(&a) == best_bits(&b)
                && a.archive == b.archive
        },
    );
}

#[test]
fn search_never_exceeds_60_percent_on_fig7_scenarios() {
    // The acceptance bound, on the real calibrated Fig 7 grid.
    let (rows, tasks) = grid_rows();
    let evaluator = ReplayEvaluator::new(rows);
    let base = base_request(tasks);
    let space = SearchSpace::fig7_grid();
    let ci = UseGrid::WorldAverage.g_per_joule();
    let grid = ScenarioGrid::fig7(rows, tasks, ci);
    for seed in [1u64, 7, 42, 1234, 0xC0FFEE] {
        let cfg = SearchConfig { seed, ..SearchConfig::default() };
        let out = search(&HostEngineFactory, &space, &evaluator, &base, &grid, &cfg).unwrap();
        assert!(out.converged, "seed {seed}");
        assert!(
            out.evaluations * 10 <= out.space_size * 6,
            "seed {seed}: evaluated {}/{}",
            out.evaluations,
            out.space_size
        );
    }
}
