//! Integration: the resident exploration service end to end — the
//! acceptance contract of DESIGN.md §3.6:
//!
//! * N concurrent identical sweep jobs against one service perform
//!   exactly **one** phase-A engine contraction between them (the
//!   coalescer + shared cache), and every job's result is bit-identical
//!   to the direct one-shot sweep;
//! * against a warm cache the same jobs perform **zero** contractions;
//! * a killed server (dropped `Service`) re-opened over the same state
//!   directory resumes every in-flight job — including one paused
//!   mid-search with a live checkpoint — and finishes bit-identically
//!   to an uninterrupted server;
//! * the HTTP surface round-trips over a real socket: submit, poll,
//!   fetch the result.
//!
//! "Bit-identical" is checked on the tables' headers + rows (every
//! metric, formatted from the same f64 bits). Titles are excluded on
//! purpose: they embed run observables — thread counts, cache
//! hit/miss tallies — that legitimately differ between a cold job, a
//! warm job and the direct run.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::Path;
use std::sync::Arc;

use xrcarbon::configfmt::{parse, Json};
use xrcarbon::dse::grid::ScenarioGrid;
use xrcarbon::dse::sweep::{sweep, SweepConfig};
use xrcarbon::experiments::sweep_fig7;
use xrcarbon::report::{sweep_best_table, sweep_table, Table};
use xrcarbon::runtime::HostEngineFactory;
use xrcarbon::service::{spawn_listener, ResultFetch, Service, ServiceConfig, Submit};
use xrcarbon::testkit::test_dir;
use xrcarbon::workloads::Cluster;

fn open_service(dir: &Path) -> Service {
    Service::open(ServiceConfig {
        state_dir: dir.to_path_buf(),
        cache_dir: None,
        cache_budget: None,
        threads: 1,
        engine: "host".to_string(),
        auth_token: None,
    })
    .unwrap()
}

fn open_service_with_token(dir: &Path, token: &str) -> Service {
    Service::open(ServiceConfig {
        state_dir: dir.to_path_buf(),
        cache_dir: None,
        cache_budget: None,
        threads: 1,
        engine: "host".to_string(),
        auth_token: Some(token.to_string()),
    })
    .unwrap()
}

fn accepted(s: Submit) -> u64 {
    match s {
        Submit::Accepted(id) => id,
        Submit::Rejected(msg) => panic!("submission rejected: {msg}"),
    }
}

fn state_of(svc: &Service, id: u64) -> String {
    svc.job_status(id)
        .unwrap()
        .get("state")
        .and_then(Json::as_str)
        .unwrap()
        .to_string()
}

/// A table's comparison body: `(headers, rows)` rendered to canonical
/// JSON strings, title excluded (see the module doc).
fn body(t: &Json) -> (String, String) {
    (t.get("headers").unwrap().to_string(), t.get("rows").unwrap().to_string())
}

fn direct_body(t: &Table) -> (String, String) {
    body(&t.to_json())
}

/// The job's persisted tables as comparison bodies.
fn result_bodies(svc: &Service, id: u64) -> Vec<(String, String)> {
    let text = match svc.job_result(id) {
        ResultFetch::Ready(text) => text,
        ResultFetch::Failed(msg) => panic!("job {id} failed: {msg}"),
        _ => panic!("job {id} has no result"),
    };
    let doc = parse(&text).unwrap();
    assert_eq!(doc.get("id").and_then(Json::as_usize), Some(id as usize));
    let tables = doc.get("tables").and_then(Json::as_arr).unwrap();
    let rendered = doc.get("rendered").and_then(Json::as_arr).unwrap();
    assert_eq!(tables.len(), rendered.len());
    tables.iter().map(body).collect()
}

#[test]
fn concurrent_identical_sweeps_coalesce_and_match_the_direct_run() {
    let dir = test_dir("service_e2e_coalesce");
    std::fs::remove_dir_all(&dir).ok();
    let svc = open_service(&dir);

    // Three identical cold jobs, three racing executors.
    let ids: Vec<u64> =
        (0..3).map(|_| accepted(svc.submit_sweep("fig7", "5ai", 1, None).unwrap())).collect();
    std::thread::scope(|s| {
        for _ in 0..3 {
            s.spawn(|| while svc.run_next(None).unwrap() {});
        }
    });
    for &id in &ids {
        assert_eq!(state_of(&svc, id), "done");
    }

    // One phase-A contraction between the three of them: one leader
    // computed and stored; everyone else waited on the in-flight slot
    // or hit the cache it had just filled.
    let cs = svc.cache().stats();
    let co = svc.coalescer().stats();
    assert_eq!(co.computed, 1, "{co:?}");
    assert_eq!(cs.writes, 1, "{cs:?}");
    assert_eq!(cs.write_errors, 0);

    // Every job's tables equal the direct one-shot sweep's, bit for bit.
    let space = sweep_fig7::profile_cluster(Cluster::Ai5);
    let grid = ScenarioGrid::fig7(&space.rows, &space.tasks, space.ci_use_g_per_j);
    let out = sweep(&HostEngineFactory, &space.base, &grid, &SweepConfig { threads: 1 }).unwrap();
    let direct = vec![direct_body(&sweep_table(&out)), direct_body(&sweep_best_table(&out))];
    for &id in &ids {
        assert_eq!(result_bodies(&svc, id), direct);
    }

    // Warm re-submissions: zero contractions, zero writes, same tables.
    let before = svc.cache().stats();
    let warm: Vec<u64> =
        (0..2).map(|_| accepted(svc.submit_sweep("fig7", "5ai", 1, None).unwrap())).collect();
    std::thread::scope(|s| {
        for _ in 0..2 {
            s.spawn(|| while svc.run_next(None).unwrap() {});
        }
    });
    let delta = svc.cache().stats().since(&before);
    assert_eq!((delta.hits, delta.misses, delta.writes), (2, 0, 0), "{delta:?}");
    for &id in &warm {
        assert_eq!(result_bodies(&svc, id), direct);
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn killed_server_resumes_in_flight_jobs_bit_identically() {
    let submit_both = |svc: &Service| -> (u64, u64) {
        let search = accepted(svc.submit_search("fig7", "5ai", 1, 0xFEED_5EED, 0).unwrap());
        let sweep = accepted(svc.submit_sweep("fig7", "5ai", 1, None).unwrap());
        (search, sweep)
    };

    // Reference: an uninterrupted server runs both jobs to completion.
    let dir_a = test_dir("service_e2e_ref");
    std::fs::remove_dir_all(&dir_a).ok();
    let reference: Vec<Vec<(String, String)>> = {
        let svc = open_service(&dir_a);
        let (search, sweep) = submit_both(&svc);
        while svc.run_next(None).unwrap() {}
        vec![result_bodies(&svc, search), result_bodies(&svc, sweep)]
    };
    std::fs::remove_dir_all(&dir_a).ok();

    // Interrupted: the search runs exactly one generation, then the
    // process "dies" (the Service is dropped mid-queue).
    let dir_b = test_dir("service_e2e_resume");
    std::fs::remove_dir_all(&dir_b).ok();
    let (search, sweep) = {
        let svc = open_service(&dir_b);
        let ids = submit_both(&svc);
        assert!(svc.run_next(Some(1)).unwrap());
        // Paused mid-search: re-queued, with a live checkpoint on disk.
        assert_eq!(state_of(&svc, ids.0), "queued");
        assert!(dir_b.join(format!("job_{}.ckpt.json", ids.0)).exists());
        ids
    };

    // Restart: both jobs come back queued (specs re-scanned), resume
    // from the persisted state and finish identically to the reference.
    let svc = open_service(&dir_b);
    assert_eq!(state_of(&svc, search), "queued");
    assert_eq!(state_of(&svc, sweep), "queued");
    while svc.run_next(None).unwrap() {}
    assert_eq!(result_bodies(&svc, search), reference[0]);
    assert_eq!(result_bodies(&svc, sweep), reference[1]);
    // Finished jobs retire their checkpoints; the durable record is the
    // spec + result pair.
    assert!(!dir_b.join(format!("job_{search}.ckpt.json")).exists());
    assert!(dir_b.join(format!("job_{search}.result.json")).exists());
    std::fs::remove_dir_all(&dir_b).ok();
}

/// Minimal HTTP/1.1 client for the round-trip test.
fn http(addr: std::net::SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes()).unwrap();
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).unwrap();
    let text = String::from_utf8(raw).unwrap();
    let status: u16 =
        text.split_whitespace().nth(1).and_then(|s| s.parse().ok()).expect("status line");
    let payload = text.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    (status, payload)
}

#[test]
fn http_surface_round_trips_over_a_real_socket() {
    let dir = test_dir("service_e2e_http");
    std::fs::remove_dir_all(&dir).ok();
    let svc = Arc::new(open_service(&dir));
    let addr = spawn_listener(Arc::clone(&svc), "127.0.0.1:0").unwrap();

    // Submit over the wire; hex seeds survive the JSON surface.
    let (code, text) = http(
        addr,
        "POST",
        "/v1/search",
        r#"{"space": "fig7", "cluster": "5ai", "seed": "0xFEED5EED", "threads": 1}"#,
    );
    assert_eq!(code, 202, "{text}");
    let id = parse(&text).unwrap().get("job").and_then(Json::as_usize).unwrap() as u64;

    let (code, text) = http(addr, "GET", &format!("/v1/jobs/{id}"), "");
    assert_eq!(code, 200);
    assert_eq!(parse(&text).unwrap().get("state").and_then(Json::as_str), Some("queued"));
    // Result before the job ran: a conflict, not an error.
    assert_eq!(http(addr, "GET", &format!("/v1/jobs/{id}/result"), "").0, 409);

    // Run the queue (inline executor), then fetch the result by HTTP —
    // it must equal the in-process view byte for byte.
    while svc.run_next(None).unwrap() {}
    let (code, text) = http(addr, "GET", &format!("/v1/jobs/{id}/result"), "");
    assert_eq!(code, 200);
    match svc.job_result(id) {
        ResultFetch::Ready(expect) => assert_eq!(text, expect),
        _ => panic!("job should be done"),
    }
    let (code, text) = http(addr, "GET", "/v1/stats", "");
    assert_eq!(code, 200);
    assert!(parse(&text).unwrap().get("coalescer").is_some());
    let (code, _) = http(addr, "GET", "/v1/nope", "");
    assert_eq!(code, 404);
    std::fs::remove_dir_all(&dir).ok();
}

/// Like [`http`] but with an optional raw `Authorization` header value;
/// returns the status code plus the whole response text (headers
/// included, so the 401 challenge is assertable).
fn http_auth(
    addr: std::net::SocketAddr,
    method: &str,
    path: &str,
    body: &str,
    auth: Option<&str>,
) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    let auth_line = auth.map(|v| format!("Authorization: {v}\r\n")).unwrap_or_default();
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: test\r\n{auth_line}Content-Length: {}\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes()).unwrap();
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).unwrap();
    let text = String::from_utf8(raw).unwrap();
    let status: u16 =
        text.split_whitespace().nth(1).and_then(|s| s.parse().ok()).expect("status line");
    (status, text)
}

#[test]
fn auth_token_gates_every_request_with_401() {
    let dir = test_dir("service_e2e_auth");
    std::fs::remove_dir_all(&dir).ok();
    let svc = Arc::new(open_service_with_token(&dir, "s3cret-token"));
    let addr = spawn_listener(Arc::clone(&svc), "127.0.0.1:0").unwrap();

    // Missing header: 401 with the Bearer challenge, before routing.
    let (code, text) = http_auth(addr, "GET", "/v1/stats", "", None);
    assert_eq!(code, 401, "{text}");
    assert!(text.contains("WWW-Authenticate: Bearer"), "{text}");
    // Wrong token, a strict prefix of the real one, and the right
    // credential under the wrong scheme are all equally 401.
    assert_eq!(http_auth(addr, "GET", "/v1/stats", "", Some("Bearer wrong")).0, 401);
    assert_eq!(http_auth(addr, "GET", "/v1/stats", "", Some("Bearer s3cret")).0, 401);
    assert_eq!(http_auth(addr, "GET", "/v1/stats", "", Some("Basic s3cret-token")).0, 401);
    // Unauthenticated submissions never reach the router: 401, not 202.
    let (code, _) = http_auth(addr, "POST", "/v1/sweep", r#"{"preset":"fig7"}"#, None);
    assert_eq!(code, 401);

    // The correct token restores normal routing end to end.
    let token = Some("Bearer s3cret-token");
    let (code, text) = http_auth(addr, "GET", "/v1/stats", "", token);
    assert_eq!(code, 200, "{text}");
    assert_eq!(http_auth(addr, "GET", "/v1/nope", "", token).0, 404);
    let (code, text) = http_auth(addr, "POST", "/v1/sweep", r#"{"preset":"fig7","threads":1}"#, token);
    assert_eq!(code, 202, "{text}");
    std::fs::remove_dir_all(&dir).ok();
}

/// Write raw bytes, optionally half-close the write side, read the
/// response. Lets the tests below send requests no sane client would.
fn http_raw(addr: std::net::SocketAddr, raw: &[u8], half_close: bool) -> u16 {
    let mut stream = TcpStream::connect(addr).unwrap();
    // The server is allowed to respond-and-close before the whole
    // request is written; a failed tail write is part of the scenario.
    let _ = stream.write_all(raw);
    if half_close {
        let _ = stream.shutdown(std::net::Shutdown::Write);
    }
    let mut buf = Vec::new();
    stream.read_to_end(&mut buf).unwrap();
    let text = String::from_utf8_lossy(&buf);
    text.split_whitespace().nth(1).and_then(|s| s.parse().ok()).expect("status line")
}

#[test]
fn malformed_requests_get_400_not_a_worker_panic() {
    let dir = test_dir("service_e2e_malformed");
    std::fs::remove_dir_all(&dir).ok();
    let svc = Arc::new(open_service(&dir));
    let addr = spawn_listener(Arc::clone(&svc), "127.0.0.1:0").unwrap();

    // Garbage request line: not HTTP at all.
    assert_eq!(http_raw(addr, b"GARBAGE\r\n\r\n", false), 400);
    // Three tokens but no HTTP/ version, and a path with no leading /.
    assert_eq!(http_raw(addr, b"GET /v1/stats FTP/1.0\r\n\r\n", false), 400);
    assert_eq!(http_raw(addr, b"GET v1stats HTTP/1.1\r\n\r\n", false), 400);
    // Content-Length that doesn't parse must be rejected, not read as 0.
    assert_eq!(
        http_raw(addr, b"POST /v1/sweep HTTP/1.1\r\nContent-Length: banana\r\n\r\n", false),
        400
    );
    // Truncated body: the client promises 100 bytes and hangs up after 4.
    assert_eq!(
        http_raw(addr, b"POST /v1/sweep HTTP/1.1\r\nContent-Length: 100\r\n\r\n{\"a\"", true),
        400
    );
    // Oversized declared body and oversized headers keep their codes.
    assert_eq!(
        http_raw(addr, b"POST /v1/sweep HTTP/1.1\r\nContent-Length: 9999999\r\n\r\n", false),
        413
    );
    // Sized so the server's limit trips exactly when the last byte is
    // consumed — nothing is left unread, so the close can't RST away
    // the 431 before the client reads it.
    let mut big = b"GET /v1/stats HTTP/1.1\r\n".to_vec();
    let pad = 64 * 1024 + 1 - big.len();
    big.extend(std::iter::repeat(b'x').take(pad));
    assert_eq!(http_raw(addr, &big, false), 431);

    // The listener survived all of it: a well-formed request still works.
    assert_eq!(http(addr, "GET", "/v1/stats", "").0, 200);
    // And an unknown-but-well-formed path is still a 404, not a 400.
    assert_eq!(http(addr, "GET", "/v1/nope", "").0, 404);
    std::fs::remove_dir_all(&dir).ok();
}
