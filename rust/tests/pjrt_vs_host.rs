//! Integration: the PJRT engine (AOT HLO artifacts through the XLA CPU
//! client) must agree with the pure-Rust host mirror to rounding level.
//!
//! Requires `make artifacts` to have run; tests are skipped (with a loud
//! message) when the artifacts directory is absent so `cargo test` works
//! in a fresh checkout. The whole file is additionally gated on the
//! `pjrt` cargo feature: the default host-only build has no XLA client,
//! so these tests compile to nothing there.

#![cfg(feature = "pjrt")]

use xrcarbon::dse::batching::evaluate_chunked;
use xrcarbon::matrixform::{ConfigRow, EvalRequest, MetricRow, TaskMatrix, NUM_METRICS};
use xrcarbon::runtime::{evaluate, Engine, HostEngine, PjrtEngine};
use xrcarbon::testkit::Rng;

fn artifacts_dir() -> Option<String> {
    let dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    if std::path::Path::new(&dir).join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

fn random_request(rng: &mut Rng, c: usize, t: usize, k: usize, j: usize) -> EvalRequest {
    let mut tasks = TaskMatrix::new(
        (0..t).map(|i| format!("t{i}")).collect(),
        (0..k).map(|i| format!("k{i}")).collect(),
    );
    for ti in 0..t {
        for ki in 0..k {
            if rng.chance(0.7) {
                tasks.set(ti, ki, rng.below(40) as f64);
            }
        }
    }
    let configs = (0..c)
        .map(|i| ConfigRow {
            name: format!("cfg{i}"),
            f_clk: rng.range(0.5e9, 2.0e9),
            d_k: (0..k).map(|_| rng.range(1e-4, 5e-2)).collect(),
            e_dyn: (0..k).map(|_| rng.range(1e-3, 0.5)).collect(),
            leak_w: rng.range(0.001, 0.1),
            c_comp: (0..j).map(|_| rng.range(5.0, 800.0)).collect(),
        })
        .collect();
    EvalRequest {
        tasks,
        configs,
        online: (0..j).map(|_| if rng.chance(0.8) { 1.0 } else { 0.0 }).collect(),
        qos: (0..t)
            .map(|_| if rng.chance(0.3) { rng.range(0.5, 50.0) } else { f64::INFINITY })
            .collect(),
        ci_use_g_per_j: 1.2e-4,
        lifetime_s: rng.range(1e5, 1e8),
        beta: rng.range(0.0, 3.0),
        p_max_w: if rng.chance(0.5) { rng.range(1.0, 50.0) } else { f64::INFINITY },
    }
}

fn assert_results_close(
    a: &xrcarbon::matrixform::EvalResult,
    b: &xrcarbon::matrixform::EvalResult,
    tag: &str,
) {
    assert_eq!(a.c, b.c);
    for row in 0..NUM_METRICS {
        for ci in 0..a.c {
            let (x, y) = (a.metrics[row * a.c + ci], b.metrics[row * b.c + ci]);
            let denom = x.abs().max(y.abs()).max(1e-12);
            assert!(
                (x - y).abs() / denom < 2e-4,
                "{tag}: metric row {row} config {ci}: pjrt={x} host={y}"
            );
        }
    }
    for (i, (x, y)) in a.d_task.iter().zip(&b.d_task).enumerate() {
        let denom = x.abs().max(y.abs()).max(1e-12);
        assert!((x - y).abs() / denom < 2e-4, "{tag}: d_task[{i}]: {x} vs {y}");
    }
}

#[test]
fn pjrt_loads_all_variants() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = PjrtEngine::load(&dir).expect("PJRT engine load");
    assert_eq!(engine.variants(), vec![128, 1024]);
    assert_eq!(engine.platform(), "cpu");
}

#[test]
fn pjrt_matches_host_on_random_batches() {
    let Some(dir) = artifacts_dir() else { return };
    let mut pjrt = PjrtEngine::load(&dir).expect("PJRT engine load");
    let mut host = HostEngine::new();
    let mut rng = Rng::new(0xA11CE);
    for trial in 0..8 {
        let c = [1, 3, 17, 121, 128, 129, 700, 1024][trial];
        let req = random_request(&mut rng, c, 4, 12, 6);
        let rp = evaluate(&mut pjrt, &req).expect("pjrt eval");
        let rh = evaluate(&mut host, &req).expect("host eval");
        assert_results_close(&rp, &rh, &format!("trial {trial} (c={c})"));
    }
}

#[test]
fn pjrt_chunked_large_space() {
    let Some(dir) = artifacts_dir() else { return };
    let mut pjrt = PjrtEngine::load(&dir).expect("PJRT engine load");
    let mut host = HostEngine::new();
    let mut rng = Rng::new(7);
    let req = random_request(&mut rng, 2100, 2, 8, 4);
    let rp = evaluate_chunked(&mut pjrt, &req).expect("pjrt chunked");
    let rh = evaluate_chunked(&mut host, &req).expect("host chunked");
    assert_results_close(&rp, &rh, "chunked-2100");
}

#[test]
fn pjrt_feasibility_matches_host_exactly() {
    // Feasibility is a 0/1 decision — it must agree exactly, not just
    // within tolerance, across a constraint-heavy batch.
    let Some(dir) = artifacts_dir() else { return };
    let mut pjrt = PjrtEngine::load(&dir).expect("PJRT engine load");
    let mut host = HostEngine::new();
    let mut rng = Rng::new(99);
    let mut req = random_request(&mut rng, 128, 3, 6, 4);
    req.qos = vec![5.0, 2.0, f64::INFINITY];
    req.p_max_w = 10.0;
    let rp = evaluate(&mut pjrt, &req).unwrap();
    let rh = evaluate(&mut host, &req).unwrap();
    let fp = rp.row(MetricRow::Feasible);
    let fh = rh.row(MetricRow::Feasible);
    // Values right at a constraint boundary could legitimately differ by
    // one ulp of rounding; with random data that's measure-zero. Require
    // exact agreement.
    assert_eq!(fp, fh);
    assert!(fp.iter().any(|&f| f == 0.0), "constraint never binds — weak test");
    assert!(fp.iter().any(|&f| f == 1.0), "no feasible configs — weak test");
}

#[test]
fn engine_reports_names() {
    let Some(dir) = artifacts_dir() else { return };
    let pjrt = PjrtEngine::load(&dir).expect("load");
    assert_eq!(Engine::name(&pjrt), "pjrt");
    assert_eq!(Engine::name(&HostEngine::new()), "host");
}
