//! Operational-lifetime studies (paper §5.3 and §5.5, Figs 10/14): the
//! embodied/operational crossovers of A-1..A-4 and the carbon-optimal
//! replacement period.
//!
//!     cargo run --release --example lifetime_sweep

use xrcarbon::experiments::common::Ctx;
use xrcarbon::experiments::{fig10_lifetime_crossover as fig10, fig14_replacement};
use xrcarbon::report::ascii_series;

fn main() -> anyhow::Result<()> {
    let mut ctx = Ctx::auto();
    println!("engine: {}\n", ctx.backend);
    let f = fig10::run(ctx.engine.as_mut(), &fig10::default_axis())?;
    print!("{}", f.table.render());
    let labels: Vec<String> = f.n_inf.iter().map(|n| format!("{:.0}", n.log10())).collect();
    let series: Vec<(&str, Vec<f64>)> = f
        .series
        .iter()
        .map(|(n, v)| (n.as_str(), v.iter().map(|x| x.log10()).collect()))
        .collect();
    println!("{}", ascii_series(&labels, &series, 60));
    print!("{}", fig14_replacement::run().table.render());
    Ok(())
}
