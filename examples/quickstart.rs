//! Quickstart: run a small carbon-aware design space exploration and
//! print the tCDP-optimal accelerator for the 5-AI workload cluster.
//!
//!     cargo run --release --example quickstart

use xrcarbon::dse::{design_grid, explore, lifetime_for_ratio, profile_configs, profiles_to_rows};
use xrcarbon::carbon::FabGrid;
use xrcarbon::experiments::common::{default_use_grid, rows_request, suite_task, Ctx};
use xrcarbon::matrixform::MetricRow;
use xrcarbon::workloads::{cluster_workloads, Cluster};

fn main() -> anyhow::Result<()> {
    // 1. Enumerate the hardware design space (121 MAC×SRAM points).
    let grid = design_grid();
    let configs: Vec<_> = grid.iter().map(|p| p.config.clone()).collect();

    // 2. Profile the cluster's kernels on every candidate (Fig 6 simulator).
    let workloads = cluster_workloads(Cluster::Ai5);
    let profiles = profile_configs(&configs, &workloads);
    let rows = profiles_to_rows(&configs, &profiles, FabGrid::Coal);

    // 3. Pick a carbon scenario (65% embodied share) and evaluate the
    //    whole space through the XLA runtime in one batch.
    let ci = default_use_grid().g_per_joule();
    let lifetime = lifetime_for_ratio(&rows, &suite_task(&workloads), 0.65, ci);
    let req = rows_request(rows, &workloads, lifetime, 1.0);

    let mut ctx = Ctx::auto();
    println!("engine: {}", ctx.backend);
    let out = explore(ctx.engine.as_mut(), &req)?;

    // 4. Report the optimum.
    let best = out.optimal["tCDP"];
    println!(
        "tCDP-optimal design for {:?}: {}  (tCDP {:.3e} g*s; {} feasible designs)",
        Cluster::Ai5,
        out.result.names[best],
        out.result.metric(MetricRow::Tcdp, best),
        out.stats.feasible,
    );
    let edp = out.optimal["EDP"];
    println!(
        "EDP would have picked:        {}  (its tCDP is {:.2}x worse)",
        out.result.names[edp],
        out.result.metric(MetricRow::Tcdp, edp) / out.result.metric(MetricRow::Tcdp, best)
    );
    Ok(())
}
