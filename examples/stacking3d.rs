//! 3-D integration case study (paper §5.6, Figs 15/16): carbon efficiency
//! of F2F-stacked SRAM accelerators vs the 2-D baseline.
//!
//!     cargo run --release --example stacking3d

use xrcarbon::accel::Workload;
use xrcarbon::experiments::common::Ctx;
use xrcarbon::experiments::{fig15_stacking, fig16_stacking_kernels};

fn main() -> anyhow::Result<()> {
    let mut ctx = Ctx::auto();
    println!("engine: {}\n", ctx.backend);
    print!("{}", fig15_stacking::run(ctx.engine.as_mut(), Workload::Sr512)?.table.render());
    println!();
    print!("{}", fig16_stacking_kernels::run(ctx.engine.as_mut())?.table.render());
    Ok(())
}
