//! End-to-end driver (EXPERIMENTS.md §E2E): exercises every layer of the
//! stack on the paper's full headline workload — the 121-configuration
//! MAC×SRAM design space × 5 Table 4 clusters × 3 embodied-carbon
//! scenarios — through the AOT-compiled XLA path, then cross-checks the
//! PJRT results against the pure-Rust host mirror and reports throughput.
//!
//!     make artifacts && cargo run --release --example dse_e2e

use std::time::Instant;

use xrcarbon::dse::batching::evaluate_chunked;
use xrcarbon::dse::{design_grid, explore, lifetime_for_ratio, profile_configs, profiles_to_rows};
use xrcarbon::carbon::FabGrid;
use xrcarbon::experiments::common::{default_use_grid, rows_request, suite_task};
use xrcarbon::matrixform::MetricRow;
use xrcarbon::runtime::{HostEngine, PjrtEngine};
use xrcarbon::workloads::{cluster_workloads, Cluster};

fn main() -> anyhow::Result<()> {
    let t0 = Instant::now();
    let mut pjrt = PjrtEngine::load("artifacts")?;
    println!(
        "[setup] PJRT {} engine, variants {:?}, loaded in {:?}",
        pjrt.platform(),
        pjrt.variants(),
        t0.elapsed()
    );
    let mut host = HostEngine::new();

    let grid = design_grid();
    let configs: Vec<_> = grid.iter().map(|p| p.config.clone()).collect();
    let ci = default_use_grid().g_per_joule();

    // Scenario calibration on the All cluster.
    let all_w = cluster_workloads(Cluster::All);
    let t1 = Instant::now();
    let all_profiles = profile_configs(&configs, &all_w);
    println!("[profile] 121 configs x {} kernels in {:?}", all_w.len(), t1.elapsed());
    let all_rows = profiles_to_rows(&configs, &all_profiles, FabGrid::Coal);
    let all_tasks = suite_task(&all_w);

    let mut evals = 0usize;
    let mut max_rel_err = 0.0f64;
    let t2 = Instant::now();
    for ratio in [0.98, 0.65, 0.25] {
        let lifetime = lifetime_for_ratio(&all_rows, &all_tasks, ratio, ci);
        for cluster in Cluster::ALL {
            let ws = cluster_workloads(cluster);
            let rows = if cluster == Cluster::All {
                all_rows.clone()
            } else {
                let p = profile_configs(&configs, &ws);
                profiles_to_rows(&configs, &p, FabGrid::Coal)
            };
            let req = rows_request(rows, &ws, lifetime, 1.0);
            let out = explore(&mut pjrt, &req)?;
            let href = evaluate_chunked(&mut host, &req)?;
            // Cross-check PJRT vs host on the tCDP row.
            for i in 0..out.result.c {
                let (a, b) = (
                    out.result.metric(MetricRow::Tcdp, i),
                    href.metric(MetricRow::Tcdp, i),
                );
                let rel = (a - b).abs() / a.abs().max(b.abs()).max(1e-12);
                max_rel_err = max_rel_err.max(rel);
            }
            evals += out.result.c;
            let best = out.optimal["tCDP"];
            println!(
                "[dse] {:4.0}% embodied | {:14} -> {} (tCDP {:.3e}, best/avg {:.1}x, {} feasible)",
                ratio * 100.0,
                cluster.label(),
                out.result.names[best],
                out.stats.best,
                out.stats.mean / out.stats.best,
                out.stats.feasible
            );
        }
    }
    let dt = t2.elapsed();
    println!(
        "\n[e2e] {} config-evaluations through PJRT in {:?} ({:.0} configs/s)",
        evals,
        dt,
        evals as f64 / dt.as_secs_f64()
    );
    println!("[e2e] max PJRT-vs-host relative error: {max_rel_err:.2e}");
    assert!(max_rel_err < 2e-4, "numeric drift between PJRT and host mirror");
    println!("[e2e] OK — all layers compose (Pallas kernel -> JAX graph -> HLO text -> PJRT -> coordinator)");
    Ok(())
}
