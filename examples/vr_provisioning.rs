//! VR CPU provisioning case study (paper §5.4, Figs 11–13): find the
//! carbon-optimal core configuration per application and the resulting
//! embodied/total savings.
//!
//!     cargo run --release --example vr_provisioning

use xrcarbon::experiments::common::Ctx;
use xrcarbon::experiments::{fig11_provisioning_savings, fig12_tlp_breakdown, fig13_core_configs};
use xrcarbon::workloads::FleetConfig;

fn main() -> anyhow::Result<()> {
    let mut ctx = Ctx::auto();
    println!("engine: {}\n", ctx.backend);
    print!("{}", fig12_tlp_breakdown::run(&FleetConfig::default()).table.render());
    println!();
    print!("{}", fig13_core_configs::run(ctx.engine.as_mut())?.table.render());
    println!();
    let f11 = fig11_provisioning_savings::run(ctx.engine.as_mut())?;
    print!("{}", f11.table.render());
    println!(
        "\nmean embodied saving {:.0}% | mean total saving {:.1}%",
        f11.mean_embodied_saving * 100.0,
        f11.mean_total_saving * 100.0
    );
    Ok(())
}
