"""Model `worker_pool` — fail-fast MPMC scheduling with indexed merge.

Mirrors the fenced protocol in rust/src/runtime/pool.rs (see
models.lock): workers claim envelopes off one shared channel (the recv
is atomic under the receiver mutex), check the batch's abort flag at
claim time — a set flag means reply ``Skipped`` without executing — and
otherwise run the task; a task failure sets the abort flag and replies
with the error.  The collector receives EXACTLY one reply per envelope,
keeps the LOWEST-indexed error seen so far (``is_none_or(|(j, _)| i <
*j)``), and on an error-free batch fills result slots by envelope index,
so the merged output is interleaving-independent.  An in-flight task is
deliberately NOT interrupted when another worker fails — only future
claims observe the abort.

Bounded configuration: 2 workers, 3 envelopes; tasks 1 and 2 may
nondeterministically fail (scheduler choice), task 0 always succeeds.

Invariants checked in every reachable state:
  * no worker executes an envelope whose claim-time abort check observed
    the flag set (fail-fast: abort stops all claims after first failure);
and in terminal states:
  * exactly one reply per envelope (none lost, none duplicated);
  * if any task errored, the collector reports the lowest-indexed error
    among the errors that actually ran, in EVERY interleaving;
  * an error-free batch merges to the slot-ordered outputs regardless of
    claim order or reply arrival order.
"""

from explorer import clone

N_TASKS = 3
FAILABLE = {1, 2}


def _task_value(i):
    return i * 10


MUTATIONS = {
    "first_error_by_arrival": (
        "the collector keeps the first error RECEIVED instead of the "
        "lowest-indexed one — the reported error depends on reply timing"
    ),
    "no_abort_check": (
        "workers skip the claim-time abort check and execute every "
        "envelope even after a failure poisoned the batch"
    ),
    "skip_without_reply": (
        "an aborted claim returns to the loop without sending Skipped — "
        "the collector waits for a reply that never comes"
    ),
    "merge_by_arrival": (
        "the collector appends results in reply-arrival order instead of "
        "by envelope index — the merge depends on the interleaving"
    ),
}


class PoolModel:
    name = "worker_pool"

    def __init__(self, mutation=None):
        if mutation is not None and mutation not in MUTATIONS:
            raise ValueError(f"unknown pool mutation {mutation!r}")
        self.mutation = mutation

    # -- state ---------------------------------------------------------------

    def initial(self):
        return {
            "queue": list(range(N_TASKS)),  # the MPMC channel
            "abort": False,
            "replies": [],  # (kind, idx, value) in send order
            "workers": {
                w: {"pc": "claim", "env": None, "observed_abort": False}
                for w in ("wa", "wb")
            },
            "collector": {
                "received": 0,
                "first_err": None,
                "slots": {},
                "arrival": [],
                "result": None,
                "pc": "recv",
            },
        }

    # -- transition relation -------------------------------------------------

    def actions(self, s):
        acts = []
        for wid in sorted(s["workers"]):
            w = s["workers"][wid]
            if w["pc"] == "claim":
                n = clone(s)
                nw = n["workers"][wid]
                if n["queue"]:
                    nw["env"] = n["queue"].pop(0)
                    nw["pc"] = "check"
                    acts.append((f"{wid}: recv envelope {nw['env']} off the channel", n))
                else:
                    nw["pc"] = "done"
                    acts.append((f"{wid}: channel drained — worker exits", n))
            elif w["pc"] == "check":
                n = clone(s)
                nw = n["workers"][wid]
                i = nw["env"]
                nw["observed_abort"] = n["abort"]
                if n["abort"] and self.mutation != "no_abort_check":
                    if self.mutation != "skip_without_reply":
                        n["replies"].append(("skipped", i, None))
                    nw["env"] = None
                    nw["pc"] = "claim"
                    acts.append((f"{wid}: abort set at claim — envelope {i} Skipped", n))
                else:
                    nw["pc"] = "exec"
                    acts.append((f"{wid}: abort clear at claim of envelope {i} — running"
                                 if not n["abort"] else
                                 f"{wid}: [no_abort_check] runs envelope {i} despite abort", n))
            elif w["pc"] == "exec":
                i = w["env"]
                n = clone(s)
                nw = n["workers"][wid]
                n["replies"].append(("ok", i, _task_value(i)))
                nw["env"] = None
                nw["observed_abort"] = False
                nw["pc"] = "claim"
                acts.append((f"{wid}: task {i} succeeded — replied Done(Ok)", n))
                if i in FAILABLE:
                    f = clone(s)
                    fw = f["workers"][wid]
                    f["abort"] = True
                    f["replies"].append(("err", i, None))
                    fw["env"] = None
                    fw["observed_abort"] = False
                    fw["pc"] = "claim"
                    acts.append((f"{wid}: task {i} FAILED — abort set, replied Done(Err)", f))

        col = s["collector"]
        if col["pc"] == "recv" and col["received"] < len(s["replies"]):
            n = clone(s)
            c = n["collector"]
            kind, i, value = n["replies"][c["received"]]
            c["received"] += 1
            if kind == "err":
                if self.mutation == "first_error_by_arrival":
                    if c["first_err"] is None:
                        c["first_err"] = i
                elif c["first_err"] is None or i < c["first_err"]:
                    c["first_err"] = i
            elif kind == "ok":
                c["slots"][i] = value
                c["arrival"].append(value)
            if c["received"] == N_TASKS:
                c["pc"] = "finish"
            acts.append((f"collector: received {kind}({i}) "
                         f"[{c['received']}/{N_TASKS}]", n))
        elif col["pc"] == "finish":
            n = clone(s)
            c = n["collector"]
            if c["first_err"] is not None:
                c["result"] = ("err", c["first_err"])
            elif self.mutation == "merge_by_arrival":
                c["result"] = ("ok", list(c["arrival"]))
            else:
                c["result"] = ("ok", [c["slots"][i] for i in sorted(c["slots"])])
            c["pc"] = "done"
            acts.append((f"collector: merged result {c['result']}", n))
        return acts

    # -- invariants ----------------------------------------------------------

    def check(self, s):
        for wid, w in s["workers"].items():
            if w["pc"] == "exec" and w["observed_abort"]:
                return (
                    f"{wid} is executing envelope {w['env']} although its "
                    f"claim-time check observed the abort flag — fail-fast "
                    f"must stop every claim after the first failure"
                )
        return None

    def check_final(self, s):
        for wid, w in s["workers"].items():
            if w["pc"] != "done":
                return f"deadlock: worker {wid} stuck at pc `{w['pc']}`"
        col = s["collector"]
        if col["pc"] != "done":
            return (
                f"deadlock: collector stuck at pc `{col['pc']}` with "
                f"{col['received']}/{N_TASKS} replies — some envelope never "
                f"got its exactly-one reply"
            )
        idxs = sorted(i for _, i, _ in s["replies"])
        if idxs != list(range(N_TASKS)):
            return f"reply multiset {idxs} != one reply per envelope"
        errs = sorted(i for kind, i, _ in s["replies"] if kind == "err")
        kind, payload = col["result"]
        if errs:
            if kind != "err" or payload != errs[0]:
                return (
                    f"errors {errs} occurred but the collector reported "
                    f"{col['result']} — the LOWEST-indexed error must win in "
                    f"every interleaving"
                )
        else:
            expected = [_task_value(i) for i in range(N_TASKS)]
            if kind != "ok" or payload != expected:
                return (
                    f"error-free batch merged to {col['result']} instead of "
                    f"{('ok', expected)} — merge order must be "
                    f"interleaving-independent"
                )
        return None


def build(mutation=None):
    return PoolModel(mutation)
