"""Exhaustive bounded-interleaving explorer for xrverify protocol models.

A model is a transition system over one explicit shared state:

* ``initial()``      -> the starting state (a plain dict of plain data)
* ``actions(state)`` -> every enabled scheduler choice as ``(label, next)``
                        pairs — each pair is one thread taking one atomic
                        step (or an environment event such as a crash)
* ``check(state)``   -> a violation message, or ``None`` when every safety
                        invariant holds in this state
* ``check_final(s)`` -> called on states with no enabled action; ``None``
                        means the run terminated acceptably, a message
                        means deadlock / an unacceptable final state

The explorer enumerates EVERY interleaving up to the model's bounded
configuration with a breadth-first search over hashed states, so the
first violation found is a minimal-depth counterexample.  After a clean
sweep it runs a liveness pass: every reachable state must be able to
reach an acceptable terminal state (backward reachability from the
terminal-ok set over the recorded transition graph); a state that
cannot — a cycle with no escape, e.g. a lost wakeup that parks a waiter
forever behind a spinning peer — is reported with the trace that
reaches it.

Everything here is stdlib-only: the containers this repo grows in have
no Rust toolchain (ROADMAP), so this explorer and xrlint are the
verification layer that actually executes.
"""

import copy
from collections import deque

DEFAULT_MAX_STATES = 400_000


def freeze(value):
    """Canonical hashable form of a state built from dict/list/set/scalars."""
    if isinstance(value, dict):
        return ("d",) + tuple(sorted((k, freeze(v)) for k, v in value.items()))
    if isinstance(value, (list, tuple)):
        return ("l",) + tuple(freeze(v) for v in value)
    if isinstance(value, (set, frozenset)):
        return ("s",) + tuple(sorted(freeze(v) for v in value))
    return value


def clone(state):
    """Successor builder: models mutate a deep copy, never the original."""
    return copy.deepcopy(state)


class Violation:
    """kind is 'safety', 'deadlock' or 'liveness'; trace is the step-label
    path from the initial state to the offending state."""

    def __init__(self, kind, message, trace):
        self.kind = kind
        self.message = message
        self.trace = trace

    def render(self, model_name):
        lines = [
            f"xrverify: {self.kind} violation in model `{model_name}`",
            f"  invariant: {self.message}",
            f"  counterexample ({len(self.trace)} steps, minimal depth):",
        ]
        if not self.trace:
            lines.append("    (violated in the initial state)")
        for n, label in enumerate(self.trace, 1):
            lines.append(f"    {n:3d}. {label}")
        return "\n".join(lines)


class Result:
    def __init__(self, model_name, states, transitions, terminals, violation):
        self.model_name = model_name
        self.states = states
        self.transitions = transitions
        self.terminals = terminals
        self.violation = violation

    @property
    def ok(self):
        return self.violation is None


def _trace_of(parents, key):
    steps = []
    while parents[key] is not None:
        key, label = parents[key]
        steps.append(label)
    steps.reverse()
    return steps


def explore(model, max_states=DEFAULT_MAX_STATES):
    init = model.initial()
    k0 = freeze(init)
    parents = {k0: None}  # key -> None | (parent key, step label)
    states = {k0: init}
    preds = {}  # key -> [predecessor keys] for the liveness pass
    transitions = 0
    terminal_ok = []

    msg = model.check(init)
    if msg is not None:
        return Result(model.name, 1, 0, 0, Violation("safety", msg, []))

    frontier = deque([k0])
    while frontier:
        key = frontier.popleft()
        acts = model.actions(states[key])
        if not acts:
            fmsg = model.check_final(states[key])
            if fmsg is not None:
                return Result(
                    model.name, len(parents), transitions, len(terminal_ok),
                    Violation("deadlock", fmsg, _trace_of(parents, key)),
                )
            terminal_ok.append(key)
            continue
        for label, nxt in acts:
            transitions += 1
            nk = freeze(nxt)
            preds.setdefault(nk, []).append(key)
            if nk in parents:
                continue
            parents[nk] = (key, label)
            states[nk] = nxt
            smsg = model.check(nxt)
            if smsg is not None:
                return Result(
                    model.name, len(parents), transitions, len(terminal_ok),
                    Violation("safety", smsg, _trace_of(parents, nk)),
                )
            if len(parents) > max_states:
                raise RuntimeError(
                    f"model `{model.name}` exceeded {max_states} states — "
                    f"tighten its bounded configuration"
                )
            frontier.append(nk)

    # Liveness: backward reachability from the terminal-ok set.  Every
    # reachable state must have SOME schedule that terminates acceptably;
    # a state outside this set sits in a cycle (or feeds only cycles)
    # with no escape — a livelock / lost-wakeup signature.
    can_finish = set(terminal_ok)
    work = deque(terminal_ok)
    while work:
        key = work.popleft()
        for p in preds.get(key, ()):
            if p not in can_finish:
                can_finish.add(p)
                work.append(p)
    for key in parents:  # insertion order is BFS order => minimal depth first
        if key not in can_finish:
            return Result(
                model.name, len(parents), transitions, len(terminal_ok),
                Violation(
                    "liveness",
                    "state cannot reach any acceptable terminal state under "
                    "any schedule (livelock / lost wakeup)",
                    _trace_of(parents, key),
                ),
            )
    return Result(model.name, len(parents), transitions, len(terminal_ok), None)
