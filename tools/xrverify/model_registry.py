"""Model `job_registry` — crash-resumable enqueue/complete persistence.

Mirrors the fenced protocol in rust/src/service/jobs.rs (see
models.lock): ``enqueue`` allocates the id inside one registry critical
section, atomic-writes the spec OUTSIDE the lock (the PR 9 L002 fix —
disk latency must never ride on the lock every status poll takes), then
inserts + queues inside a second critical section.  The executor pops a
job, persists a checkpoint, persists the result, and only then deletes
the checkpoint.  ``Registry::scan`` on restart rebuilds the registry
from durable state alone: a spec with a result is Done, a spec without
one is re-queued in id order, and ``next_id`` resumes at max+1.

Bounded configuration: two enqueuers and one executor run pre-crash; a
crash may be injected between ANY two steps (single fault); restart
scans and a post-restart enqueuer + executor drain the registry.

Invariants checked in every reachable state:
  * no filesystem write while the registry lock is held (the L002 bug);
  * a job visible in the queue always has a durable spec
    (visible => durable, the crash-resume ack contract);
  * an id is never spec-written twice (no duplicated job);
  * a job whose result is durable is never run again.
Terminal states require every durable spec to own a durable result (no
lost job — an id allocated but never spec-written is an id GAP, which
the contract allows) and each job run at most... exactly once per
durable result.
"""

from explorer import clone

MUTATIONS = {
    "spec_write_under_lock": (
        "enqueue atomic-writes the spec inside the registry critical "
        "section — the actual PR 9 L002 bug: every status poll now rides "
        "on disk latency"
    ),
    "insert_before_spec_write": (
        "enqueue makes the job visible in the queue before its spec is "
        "durable — a crash in between acks a job that restart cannot see"
    ),
    "next_id_from_count": (
        "scan resumes next_id from the COUNT of durable specs instead of "
        "max+1 — an id gap makes a fresh enqueue collide with a live job"
    ),
    "requeue_if_ckpt": (
        "scan re-queues any spec with a leftover checkpoint even when its "
        "result is durable — a crash between result-write and ckpt-delete "
        "runs the job twice"
    ),
}

PRE_ENQ = ("e0", "e1")


class RegistryModel:
    name = "job_registry"

    def __init__(self, mutation=None):
        if mutation is not None and mutation not in MUTATIONS:
            raise ValueError(f"unknown registry mutation {mutation!r}")
        self.mutation = mutation

    # -- state ---------------------------------------------------------------

    def initial(self):
        threads = {}
        for e in PRE_ENQ:
            threads[e] = {"pc": "lock1", "id": None}
        threads["x"] = {"pc": "pop", "job": None}  # pre-crash executor
        threads["e2"] = {"pc": "await_restart", "id": None}  # post-restart
        threads["x2"] = {"pc": "await_restart", "job": None}
        return {
            "durable": {"specs": [], "results": [], "ckpts": []},  # sorted id lists
            "mem": {"next_id": 0, "queue": [], "jobs": {}},
            "lock": None,  # registry-lock holder tid
            "crashed": False,
            "restarted": False,
            "io_under_lock": None,  # tid that wrote durable state while locked
            "dup_spec": None,  # id spec-written twice
            "ran_after_result": None,  # id run again after its result landed
            "threads": threads,
        }

    # -- helpers -------------------------------------------------------------

    def _write_spec(self, n, tid):
        th = n["threads"][tid]
        if n["lock"] == tid:
            n["io_under_lock"] = tid
        if th["id"] in n["durable"]["specs"]:
            n["dup_spec"] = th["id"]
        else:
            n["durable"]["specs"] = sorted(n["durable"]["specs"] + [th["id"]])

    def _enqueuer_steps(self, s, tid, acts):
        th = s["threads"][tid]
        pc = th["pc"]
        under_lock = self.mutation == "spec_write_under_lock"
        if pc == "lock1" and s["lock"] is None:
            n = clone(s)
            n["lock"] = tid
            n["threads"][tid]["pc"] = "alloc"
            acts.append((f"{tid}: acquire registry lock (critical section 1)", n))
        elif pc == "alloc":
            n = clone(s)
            t = n["threads"][tid]
            t["id"] = n["mem"]["next_id"]
            n["mem"]["next_id"] += 1
            if under_lock:
                t["pc"] = "write_spec"
            elif self.mutation == "insert_before_spec_write":
                t["pc"] = "insert"  # stays inside critical section 1
            else:
                t["pc"] = "unlock1"
            acts.append((f"{tid}: allocated job id {t['id']} under the lock", n))
        elif pc == "unlock1":
            n = clone(s)
            n["lock"] = None
            n["threads"][tid]["pc"] = "write_spec"
            acts.append((f"{tid}: release registry lock before the spec write", n))
        elif pc == "write_spec":
            n = clone(s)
            self._write_spec(n, tid)
            t = n["threads"][tid]
            if under_lock:
                t["pc"] = "insert"  # still inside the critical section
            elif self.mutation == "insert_before_spec_write":
                t["pc"] = "done"  # insert + unlock already happened
            else:
                t["pc"] = "lock2"
            acts.append((f"{tid}: atomic_write spec for job {t['id']} (durable)", n))
        elif pc == "lock2" and s["lock"] is None:
            n = clone(s)
            n["lock"] = tid
            n["threads"][tid]["pc"] = "insert"
            acts.append((f"{tid}: re-acquire registry lock (critical section 2)", n))
        elif pc == "insert":
            n = clone(s)
            t = n["threads"][tid]
            n["mem"]["jobs"][t["id"]] = "queued"
            n["mem"]["queue"].append(t["id"])
            if self.mutation == "insert_before_spec_write":
                t["pc"] = "unlock1b"
            else:
                t["pc"] = "unlock2"
            acts.append((f"{tid}: insert job {t['id']} into registry + queue", n))
        elif pc == "unlock1b":  # insert_before_spec_write: unlock, then write
            n = clone(s)
            n["lock"] = None
            n["threads"][tid]["pc"] = "write_spec"
            acts.append((f"{tid}: [insert_before_spec_write] unlock, spec still not durable", n))
        elif pc == "unlock2":
            n = clone(s)
            n["lock"] = None
            n["threads"][tid]["pc"] = "done"
            acts.append((f"{tid}: release registry lock — enqueue({n['threads'][tid]['id']}) acked", n))

    def _executor_steps(self, s, tid, acts, enqueuers):
        th = s["threads"][tid]
        pc = th["pc"]
        if pc == "pop":
            if s["mem"]["queue"]:
                if s["lock"] is None:
                    n = clone(s)
                    t = n["threads"][tid]
                    t["job"] = n["mem"]["queue"].pop(0)  # one critical section
                    n["mem"]["jobs"][t["job"]] = "running"
                    if t["job"] in n["durable"]["results"]:
                        n["ran_after_result"] = t["job"]
                    t["pc"] = "ckpt"
                    acts.append((f"{tid}: popped job {t['job']} (Queued -> Running)", n))
            elif all(s["threads"][e]["pc"] == "done" for e in enqueuers):
                n = clone(s)
                n["threads"][tid]["pc"] = "done"
                acts.append((f"{tid}: queue drained, enqueuers done — executor exits", n))
        elif pc == "ckpt":
            n = clone(s)
            t = n["threads"][tid]
            if t["job"] not in n["durable"]["ckpts"]:
                n["durable"]["ckpts"] = sorted(n["durable"]["ckpts"] + [t["job"]])
            t["pc"] = "result"
            acts.append((f"{tid}: atomic_write checkpoint for job {t['job']}", n))
        elif pc == "result":
            n = clone(s)
            t = n["threads"][tid]
            if t["job"] not in n["durable"]["results"]:
                n["durable"]["results"] = sorted(n["durable"]["results"] + [t["job"]])
            t["pc"] = "del_ckpt"
            acts.append((f"{tid}: atomic_write result for job {t['job']} (durable)", n))
        elif pc == "del_ckpt":
            n = clone(s)
            t = n["threads"][tid]
            n["durable"]["ckpts"] = [c for c in n["durable"]["ckpts"] if c != t["job"]]
            n["mem"]["jobs"][t["job"]] = "done"
            t["job"] = None
            t["pc"] = "pop"
            acts.append((f"{tid}: delete checkpoint — job retired (Done)", n))

    # -- transition relation -------------------------------------------------

    def actions(self, s):
        acts = []
        if not s["crashed"]:
            for e in PRE_ENQ:
                self._enqueuer_steps(s, e, acts)
            self._executor_steps(s, "x", acts, PRE_ENQ)
            # The fault: a crash may strike between ANY two steps (once).
            n = clone(s)
            n["crashed"] = True
            n["mem"] = None
            n["lock"] = None
            for t in (*PRE_ENQ, "x"):
                n["threads"][t]["pc"] = "dead"
                if "job" in n["threads"][t]:
                    n["threads"][t]["job"] = None
            acts.append(("CRASH: process dies — all in-memory state lost", n))
        elif not s["restarted"]:
            n = clone(s)
            n["restarted"] = True
            d = n["durable"]
            if self.mutation == "next_id_from_count":
                next_id = len(d["specs"])
            else:
                next_id = (max(d["specs"]) + 1) if d["specs"] else 0
            jobs, queue = {}, []
            for i in d["specs"]:  # sorted => re-queued in id order
                if i in d["results"] and not (
                    self.mutation == "requeue_if_ckpt" and i in d["ckpts"]
                ):
                    jobs[i] = "done"
                else:
                    jobs[i] = "queued"
                    queue.append(i)
            n["mem"] = {"next_id": next_id, "queue": queue, "jobs": jobs}
            n["threads"]["e2"]["pc"] = "lock1"
            n["threads"]["x2"]["pc"] = "pop"
            acts.append((f"RESTART: scan rebuilt registry (re-queued {queue}, "
                         f"next_id={next_id})", n))
        else:
            self._enqueuer_steps(s, "e2", acts)
            self._executor_steps(s, "x2", acts, ("e2",))
        return acts

    # -- invariants ----------------------------------------------------------

    def check(self, s):
        if s["io_under_lock"] is not None:
            return (
                f"{s['io_under_lock']} performed a filesystem write while "
                f"holding the registry lock — every status poll now rides on "
                f"disk latency (L002)"
            )
        if s["dup_spec"] is not None:
            return (
                f"job id {s['dup_spec']} was spec-written twice — a restarted "
                f"registry handed out a live job's id (duplicated job)"
            )
        if s["ran_after_result"] is not None:
            return (
                f"job {s['ran_after_result']} ran again after its result was "
                f"already durable (duplicated job)"
            )
        if s["mem"] is not None:
            for i in s["mem"]["queue"]:
                if i not in s["durable"]["specs"]:
                    return (
                        f"job {i} is visible in the queue without a durable "
                        f"spec — a crash here loses an acked job"
                    )
            if len(set(s["mem"]["queue"])) != len(s["mem"]["queue"]):
                return f"queue holds a duplicate id: {s['mem']['queue']}"
        return None

    def check_final(self, s):
        for tid, th in s["threads"].items():
            if th["pc"] not in ("done", "dead", "await_restart"):
                return f"deadlock: {tid} stuck at pc `{th['pc']}`"
        if s["crashed"] and not s["restarted"]:
            return "crashed but never restarted (explorer bug: restart is always enabled)"
        missing = [i for i in s["durable"]["specs"] if i not in s["durable"]["results"]]
        if missing:
            return (
                f"terminated with durable specs {missing} lacking results — "
                f"restart-resume lost the job(s)"
            )
        return None


def build(mutation=None):
    return RegistryModel(mutation)
