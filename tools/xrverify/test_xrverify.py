#!/usr/bin/env python3
"""Self-test for tools/xrverify (stdlib-only; runs before the real
verification in CI, like tools/xrlint/test_xrlint.py).

Four layers:
  1. Clean verification: every registered model passes exhaustively,
     with explored-state counts above per-model floors — a model whose
     state space collapses (a transition system accidentally gutted by
     an edit) fails here even though it still "passes".
  2. Mutation corpus: every seeded bug in every model's MUTATIONS table
     (>= 2 per model, including the two bugs PRs 8 and 9 fixed by hand)
     must produce an invariant violation with a readable, minimal-depth
     counterexample trace written to the trace dir.
  3. Digest-lock workflow on a copy of rust/src: editing fenced code is
     V001, deleting a fence is V002, and --update-models-lock
     re-records to a clean state.
  4. CLI contract: usage errors exit 2, not 0 or a stack trace.
"""

import os
import re
import shutil
import subprocess
import sys
import tempfile

HERE = os.path.dirname(os.path.abspath(__file__))
XRVERIFY = os.path.join(HERE, "xrverify.py")
REPO = os.path.dirname(os.path.dirname(HERE))

sys.path.insert(0, HERE)
import model_cache  # noqa: E402
import model_coalescer  # noqa: E402
import model_pool  # noqa: E402
import model_registry  # noqa: E402

# Model name -> (module, floor on explored states in the clean run).
# Floors sit well under the observed counts (140 / 1193 / 1311 / 845)
# but far above what a gutted transition system would reach.
MODELS = {
    "cache_eviction": (model_cache, 100),
    "coalescer": (model_coalescer, 800),
    "job_registry": (model_registry, 900),
    "worker_pool": (model_pool, 600),
}

failures = []


def run(*args):
    return subprocess.run(
        [sys.executable, XRVERIFY, *args], capture_output=True, text=True
    )


def check(name, ok, detail=""):
    status = "ok" if ok else "FAIL"
    print(f"[{status}] {name}")
    if not ok:
        failures.append(name)
        if detail:
            print(detail)


def main():
    # 1. The real repo verifies clean, every model exhaustively explored
    #    with a healthy state count.
    with tempfile.TemporaryDirectory() as traces:
        r = run("--trace-dir", traces)
        check("repo verifies clean", r.returncode == 0 and "xrverify: OK" in r.stdout,
              r.stdout + r.stderr)
        explored = {
            m.group(1): int(m.group(2))
            for m in re.finditer(r"model (\w+): OK — (\d+) states", r.stdout)
        }
        for name, (_, floor) in sorted(MODELS.items()):
            got = explored.get(name, 0)
            check(f"{name} explores >= {floor} states (got {got})", got >= floor,
                  r.stdout)
            check(f"{name} reports every interleaving explored",
                  re.search(rf"model {name}: OK.*every interleaving explored",
                            r.stdout) is not None, r.stdout)

    # 2. Every seeded bug is caught with a readable counterexample.
    for name, (module, _) in sorted(MODELS.items()):
        check(f"{name} seeds >= 2 mutations", len(module.MUTATIONS) >= 2,
              str(module.MUTATIONS))
        for mut in sorted(module.MUTATIONS):
            with tempfile.TemporaryDirectory() as traces:
                r = run("--mutate", f"{name}:{mut}", "--trace-dir", traces)
                out = r.stdout + r.stderr
                ok = r.returncode == 1 and "violation in model" in out
                trace = os.path.join(traces, f"{name}.{mut}.trace.txt")
                text = ""
                if os.path.exists(trace):
                    with open(trace, encoding="utf-8") as fh:
                        text = fh.read()
                ok = ok and "counterexample (" in text and text.count("\n") > 3
                check(f"mutation {name}:{mut} produces a violation trace", ok, out)

    # The two historical bugs (PR 8: mtime eviction inversion, PR 9:
    # spec write under the registry lock) must stay in the corpus.
    check("PR-8 bug seeded", "mtime_epoch_inversion" in model_cache.MUTATIONS)
    check("PR-9 bug seeded", "spec_write_under_lock" in model_registry.MUTATIONS)

    # 3. Digest-lock workflow on a scratch copy of the tree.
    with tempfile.TemporaryDirectory() as tmp:
        src = os.path.join(tmp, "src")
        shutil.copytree(os.path.join(REPO, "rust", "src"), src)
        lock = os.path.join(tmp, "models.lock")
        shutil.copy(os.path.join(HERE, "models.lock"), lock)
        traces = os.path.join(tmp, "traces")

        r = run(src, "--models-lock", lock, "--trace-dir", traces)
        check("scratch copy starts clean", r.returncode == 0, r.stdout + r.stderr)

        # Edit a line INSIDE a fenced region: drift, not a fence error.
        cache_rs = os.path.join(src, "dse", "cache.rs")
        with open(cache_rs, encoding="utf-8") as fh:
            lines = fh.readlines()
        for i, line in enumerate(lines):
            if "xrverify: model(cache_eviction)" in line:
                lines.insert(i + 1, "    // drifted: pretend the protocol changed\n")
                break
        else:
            raise AssertionError("cache_eviction fence not found")
        with open(cache_rs, "w", encoding="utf-8") as fh:
            fh.writelines(lines)
        r = run(src, "--models-lock", lock, "--trace-dir", traces)
        check("edited fenced code fails with V001",
              r.returncode == 1 and "V001" in r.stderr, r.stdout + r.stderr)

        # Re-record (the reviewed-model workflow), then clean again.
        r = run(src, "--models-lock", lock, "--update-models-lock")
        check("--update-models-lock re-records", r.returncode == 0,
              r.stdout + r.stderr)
        r = run(src, "--models-lock", lock, "--trace-dir", traces)
        check("clean after re-record", r.returncode == 0, r.stdout + r.stderr)

        # Deleting a fence is V002 — the protocol must stay locked.
        with open(cache_rs, encoding="utf-8") as fh:
            text = fh.read()
        text = text.replace("// xrverify: endmodel(cache_eviction)", "", 1)
        with open(cache_rs, "w", encoding="utf-8") as fh:
            fh.write(text)
        r = run(src, "--models-lock", lock, "--trace-dir", traces)
        check("deleted fence fails with V002",
              r.returncode == 1 and "V002" in r.stderr, r.stdout + r.stderr)

    # 4. CLI contract.
    r = run("--no-such-option")
    check("unknown option exits 2", r.returncode == 2, r.stdout + r.stderr)
    r = run("--mutate", "cache_eviction:not_a_mutation")
    check("unknown mutation exits 2", r.returncode == 2, r.stdout + r.stderr)
    r = run("--mutate", "garbage")
    check("malformed --mutate exits 2", r.returncode == 2, r.stdout + r.stderr)

    if failures:
        print(f"\n{len(failures)} xrverify self-test failure(s)", file=sys.stderr)
        return 1
    print("\nall xrverify self-tests passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
