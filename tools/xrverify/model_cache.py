"""Model `cache_eviction` — two ProfileCache handles over one directory.

Mirrors the fenced protocol in rust/src/dse/cache.rs (see models.lock):
``store()`` touches the key in the handle's recency map BEFORE any file
lands, writes envelope then sidecar under a SHARED flock (each file
individually atomic via temp+rename), and the budget pass rescans and
deletes victims under the EXCLUSIVE flock, ranking candidates with
``eviction_order`` (recency rank, then ``(mtime.is_none(), mtime)`` so a
missing mtime parks "newest", then key) and skipping ``never_evict``
entries (mtime None and foreign to this handle).  A reader validates the
sidecar before returning a hit — an envelope without its sidecar is a
miss, never data.

Bounded configuration: handles w0 (stores "a") and w1 (stores "b", then
loads "a"), a pre-existing foreign entry "old" (mtime 0) and a foreign
metadata-race entry "m" (mtime None), budget 3 entries of size 1 — so
the two stores overflow the budget by exactly one and every interleaving
must evict exactly the LRU foreign victim ("old").

Invariants checked in every reachable state:
  * a handle's completed store is still on disk, envelope AND sidecar
    (eviction never deletes a concurrent writer's just-stored entry);
  * the mtime-None entry "m" is never evicted (the PR 8 inversion bug
    stamped UNIX_EPOCH instead — "oldest, evict first");
  * no reader ever returns a hit from a torn (sidecar-less) entry;
  * flock sanity: the exclusive lock never coexists with shared holders.
Terminal states additionally require the byte budget honored and both
handles done.

MUTATIONS seed real bugs (two of them the ones PRs 8–9 fixed by hand)
and must each produce a counterexample trace — see test_xrverify.py.
"""

from explorer import clone

BUDGET = 3  # entries (uniform size 1); stores push the total to 4

MUTATIONS = {
    "mtime_epoch_inversion": (
        "mtime-read failure stamps UNIX_EPOCH instead of parking the entry "
        "'newest, never evict' — the actual PR 8 bug: ranks it oldest and "
        "evicts it first"
    ),
    "touch_rank_inverted": (
        "eviction_order compares recency ranks in descending order, so the "
        "handle's own just-touched entry sorts FIRST instead of last"
    ),
    "eviction_noop": (
        "the exclusive-lock budget pass returns without deleting anything, "
        "so the size budget is never honored"
    ),
    "trust_envelope": (
        "the reader returns a hit from the envelope without validating the "
        "sidecar — observes a torn entry mid-store"
    ),
}


class CacheModel:
    name = "cache_eviction"

    def __init__(self, mutation=None):
        if mutation is not None and mutation not in MUTATIONS:
            raise ValueError(f"unknown cache mutation {mutation!r}")
        self.mutation = mutation

    # -- state ---------------------------------------------------------------

    def initial(self):
        return {
            # key -> {env, side, mtime}; each file individually atomic.
            "disk": {
                "old": {"env": True, "side": True, "mtime": 0},
                "m": {"env": True, "side": True, "mtime": None},
            },
            "lock": {"ex": None, "sh": []},  # advisory flock on <dir>/.lock
            "clock": 10,  # mtime source for new envelopes
            "threads": {
                "w0": {"pc": "touch", "key": "a", "touched": {}, "seq": 1},
                "w1": {"pc": "touch", "key": "b", "touched": {}, "seq": 1},
            },
            "stored": {},  # tid -> key once its store completed
            "torn_hit": None,  # key, if a reader returned torn data
        }

    # -- transition relation -------------------------------------------------

    def actions(self, s):
        acts = []
        for tid in ("w0", "w1"):
            th = s["threads"][tid]
            pc = th["pc"]
            k = th["key"]
            lock = s["lock"]
            if pc == "touch":
                n = clone(s)
                t = n["threads"][tid]
                # touch-before-write: the eviction pass must never rank a
                # just-written entry as untouched.
                t["touched"][k] = t["seq"]
                t["seq"] += 1
                t["pc"] = "lock_sh"
                acts.append((f"{tid}: touch({k}) before any file lands", n))
            elif pc == "lock_sh" and lock["ex"] is None:
                n = clone(s)
                n["lock"]["sh"] = sorted(n["lock"]["sh"] + [tid])
                n["threads"][tid]["pc"] = "write_env"
                acts.append((f"{tid}: acquire SHARED flock for the store window", n))
            elif pc == "write_env":
                n = clone(s)
                n["disk"][k] = {"env": True, "side": False, "mtime": n["clock"]}
                n["clock"] += 1
                n["threads"][tid]["pc"] = "write_side"
                acts.append((f"{tid}: atomic_write envelope({k}) — entry now visible, torn", n))
            elif pc == "write_side":
                n = clone(s)
                n["disk"][k]["side"] = True
                n["threads"][tid]["pc"] = "unlock_sh"
                acts.append((f"{tid}: atomic_write sidecar({k}) — entry complete", n))
            elif pc == "unlock_sh":
                n = clone(s)
                n["lock"]["sh"] = [t for t in n["lock"]["sh"] if t != tid]
                n["stored"][tid] = k
                n["threads"][tid]["pc"] = "budget_check"
                acts.append((f"{tid}: release SHARED flock — store({k}) done", n))
            elif pc == "budget_check":
                n = clone(s)
                total = len(n["disk"])
                n["threads"][tid]["pc"] = "lock_ex" if total > BUDGET else self._after_evict(tid)
                acts.append((f"{tid}: account_write sees {total}/{BUDGET} entries", n))
            elif pc == "lock_ex" and lock["ex"] is None and not lock["sh"]:
                n = clone(s)
                n["lock"]["ex"] = tid
                n["threads"][tid]["pc"] = "evict"
                acts.append((f"{tid}: acquire EXCLUSIVE flock for the eviction pass", n))
            elif pc == "evict":
                n = clone(s)
                victims = self._evict(n, tid)
                n["threads"][tid]["pc"] = "unlock_ex"
                acts.append(
                    (f"{tid}: rescan + evict under exclusive flock "
                     f"(victims: {victims or 'none'})", n)
                )
            elif pc == "unlock_ex":
                n = clone(s)
                n["lock"]["ex"] = None
                n["threads"][tid]["pc"] = self._after_evict(tid)
                acts.append((f"{tid}: release EXCLUSIVE flock", n))
            elif pc == "read_lock" and lock["ex"] is None:
                n = clone(s)
                n["lock"]["sh"] = sorted(n["lock"]["sh"] + [tid])
                n["threads"][tid]["pc"] = "read"
                acts.append((f"{tid}: acquire SHARED flock for load(a)", n))
            elif pc == "read":
                n = clone(s)
                ent = n["disk"].get("a")
                outcome = "miss"
                if ent is not None and ent["env"]:
                    if ent["side"]:
                        outcome = "hit"
                    elif self.mutation == "trust_envelope":
                        outcome = "torn-hit"
                        n["torn_hit"] = "a"
                    # else: sidecar validation fails -> miss, never data
                n["lock"]["sh"] = [t for t in n["lock"]["sh"] if t != tid]
                n["threads"][tid]["pc"] = "done"
                acts.append((f"{tid}: load(a) under shared flock -> {outcome}", n))
        return acts

    def _after_evict(self, tid):
        return "read_lock" if tid == "w1" else "done"

    # -- the eviction pass, transcribed from cache.rs ------------------------

    def _order_key(self, touched, key, ent):
        rank = touched.get(key, 0)
        if self.mutation == "touch_rank_inverted":
            rank = -rank
        if self.mutation == "mtime_epoch_inversion":
            # The pre-PR-8 policy: a missing mtime becomes UNIX_EPOCH,
            # i.e. "oldest, evict first".
            grp = (0, -1 if ent["mtime"] is None else ent["mtime"])
        else:
            grp = (1 if ent["mtime"] is None else 0, ent["mtime"] or 0)
        return (rank, grp, key)

    def _never_evict(self, touched, key, ent):
        if self.mutation == "mtime_epoch_inversion":
            return False  # the buggy policy had no such guard
        return ent["mtime"] is None and key not in touched

    def _evict(self, n, tid):
        if self.mutation == "eviction_noop":
            return []
        touched = n["threads"][tid]["touched"]
        total = len(n["disk"])
        victims = []
        for key in sorted(n["disk"], key=lambda k: self._order_key(touched, k, n["disk"][k])):
            if total <= BUDGET:
                break
            if self._never_evict(touched, key, n["disk"][key]):
                continue
            if len(n["disk"]) - len(victims) <= 1:
                break  # never evict the last remaining entry
            victims.append(key)
            total -= 1
        for key in victims:
            del n["disk"][key]
        return victims

    # -- invariants ----------------------------------------------------------

    def check(self, s):
        for tid, key in s["stored"].items():
            ent = s["disk"].get(key)
            if ent is None or not (ent["env"] and ent["side"]):
                return (
                    f"{tid}'s just-stored entry `{key}` was deleted (or torn) "
                    f"by a concurrent eviction pass"
                )
        ent = s["disk"].get("m")
        if ent is None:
            return "the mtime-None entry `m` was evicted — None-mtime must park 'newest, never evict'"
        if s["torn_hit"] is not None:
            return f"a reader returned a hit from torn entry `{s['torn_hit']}` (envelope without sidecar)"
        if s["lock"]["ex"] is not None and s["lock"]["sh"]:
            return "flock broken: exclusive holder coexists with shared holders"
        return None

    def check_final(self, s):
        if len(s["disk"]) > BUDGET:
            return (
                f"terminated with {len(s['disk'])} entries over the "
                f"{BUDGET}-entry budget — budget must eventually be honored"
            )
        for tid, th in s["threads"].items():
            if th["pc"] != "done":
                return f"deadlock: {tid} stuck at pc `{th['pc']}`"
        return None


def build(mutation=None):
    return CacheModel(mutation)
