"""Model `coalescer` — leader/waiter in-flight request coalescing.

Mirrors the fenced protocol in rust/src/dse/coalesce.rs (see
models.lock): ``begin()`` atomically (under the inflight-map mutex)
either finds an existing slot for the key (-> waiter) or inserts a fresh
``Pending`` slot (-> leader).  The leader re-checks the cache after
winning leadership, computes, STORES the profile, and only then
publishes ``Done`` — waiters are woken only after the entry is durable —
then retires the slot from the inflight map.  A leader that dies before
resolving poisons the slot (``Failed`` + notify) from its Drop guard, so
waiters fall back to cache-then-local-compute instead of hanging.
Checking the wait predicate and going to sleep is one atomic step (the
slot mutex is held across ``Condvar::wait``), which is exactly what
makes the protocol immune to lost wakeups — and what the ``begin_race``
and ``lost_wakeup`` mutations break.

Bounded configuration: three threads request the same key; the first
leader may nondeterministically die mid-compute (one death budget).

Invariants checked in every reachable state:
  * store-before-publish: a slot is never ``Done`` while the cache is
    still empty;
  * exactly-one-leader: never two live leaders for the key;
plus termination (no deadlock, no lost wakeup — via the explorer's
liveness pass) and, in terminal states, every surviving thread holds the
correct value and a death-free run computed exactly once.
"""

from explorer import clone

VALUE = "V"

MUTATIONS = {
    "begin_race": (
        "begin() checks the inflight map and inserts the slot as two "
        "separate steps — two threads can both win leadership for one key"
    ),
    "publish_before_store": (
        "the leader publishes Done before the cache store lands — waiters "
        "wake to a value that is not durable yet"
    ),
    "lost_wakeup": (
        "resolve() sets Done but forgets notify_all — a waiter already "
        "asleep on the condvar never wakes"
    ),
    "no_poison_on_death": (
        "the leader's Drop guard retires the slot without poisoning it — "
        "sleeping waiters wait on Pending forever"
    ),
}


class CoalescerModel:
    name = "coalescer"

    def __init__(self, mutation=None):
        if mutation is not None and mutation not in MUTATIONS:
            raise ValueError(f"unknown coalescer mutation {mutation!r}")
        self.mutation = mutation

    # -- state ---------------------------------------------------------------

    def initial(self):
        return {
            "cache": None,  # the profile cache entry for the one key
            "slots": [],  # slot objects live past retirement (Arc'd)
            "inflight": None,  # index into slots, or None
            "death_budget": 1,
            "computes": 0,
            "threads": {
                t: {"pc": "check_cache", "role": None, "slot": None, "result": None}
                for t in ("t0", "t1", "t2")
            },
        }

    # -- transition relation -------------------------------------------------

    def actions(self, s):
        acts = []
        for tid in sorted(s["threads"]):
            th = s["threads"][tid]
            pc = th["pc"]
            if pc == "check_cache":
                n = clone(s)
                t = n["threads"][tid]
                if n["cache"] is not None:
                    t["result"] = n["cache"]
                    t["pc"] = "done"
                    acts.append((f"{tid}: cache hit before begin() — done", n))
                else:
                    t["pc"] = "begin_check" if self.mutation == "begin_race" else "begin"
                    acts.append((f"{tid}: cache miss — entering begin()", n))
            elif pc == "begin":
                # Atomic under the inflight-map mutex: check + insert.
                n = clone(s)
                t = n["threads"][tid]
                if n["inflight"] is not None:
                    t["role"] = "waiter"
                    t["slot"] = n["inflight"]
                    t["pc"] = "wait"
                    acts.append((f"{tid}: slot in flight — joining as waiter", n))
                else:
                    n["slots"].append({"state": "pending", "sleeping": []})
                    n["inflight"] = len(n["slots"]) - 1
                    t["role"] = "leader"
                    t["slot"] = n["inflight"]
                    t["pc"] = "recheck_cache"
                    acts.append((f"{tid}: no slot in flight — won leadership", n))
            elif pc == "begin_check":  # begin_race mutation: check...
                n = clone(s)
                t = n["threads"][tid]
                if n["inflight"] is not None:
                    t["role"] = "waiter"
                    t["slot"] = n["inflight"]
                    t["pc"] = "wait"
                    acts.append((f"{tid}: [begin_race] saw a slot — joining as waiter", n))
                else:
                    t["pc"] = "begin_insert"
                    acts.append((f"{tid}: [begin_race] saw no slot (map unlocked)", n))
            elif pc == "begin_insert":  # ...then insert, racily
                n = clone(s)
                t = n["threads"][tid]
                n["slots"].append({"state": "pending", "sleeping": []})
                n["inflight"] = len(n["slots"]) - 1
                t["role"] = "leader"
                t["slot"] = n["inflight"]
                t["pc"] = "recheck_cache"
                acts.append((f"{tid}: [begin_race] inserted slot — claims leadership", n))
            elif pc == "recheck_cache":
                n = clone(s)
                t = n["threads"][tid]
                if n["cache"] is not None:
                    t["pc"] = "publish"  # publish_cached: resolve with the cached value
                    acts.append((f"{tid}: leader re-check found the cache warm", n))
                else:
                    t["pc"] = "compute"
                    acts.append((f"{tid}: leader re-check still cold — computing", n))
            elif pc == "compute":
                n = clone(s)
                n["computes"] += 1
                n["threads"][tid]["pc"] = (
                    "publish" if self.mutation == "publish_before_store" else "store"
                )
                acts.append((f"{tid}: leader ran the phase-A contraction", n))
                if s["death_budget"] > 0:
                    d = clone(s)
                    d["death_budget"] -= 1
                    d["threads"][tid]["pc"] = "poison"
                    acts.append((f"{tid}: leader DIES mid-compute (Drop guard runs)", d))
            elif pc == "store":
                n = clone(s)
                n["cache"] = VALUE
                n["threads"][tid]["pc"] = "publish"
                acts.append((f"{tid}: leader stored the entry (durable before publish)", n))
            elif pc == "publish":
                n = clone(s)
                t = n["threads"][tid]
                slot = n["slots"][t["slot"]]
                slot["state"] = "done"
                label = f"{tid}: leader set slot Done + notify_all"
                if self.mutation != "lost_wakeup":
                    for w in slot["sleeping"]:
                        n["threads"][w]["pc"] = "consume"
                    slot["sleeping"] = []
                else:
                    label = f"{tid}: [lost_wakeup] leader set slot Done, FORGOT notify_all"
                if self.mutation == "publish_before_store":
                    t["pc"] = "late_store"
                else:
                    t["result"] = VALUE
                    t["pc"] = "retire"
                acts.append((label, n))
            elif pc == "late_store":  # publish_before_store mutation tail
                n = clone(s)
                n["cache"] = VALUE
                n["threads"][tid]["result"] = VALUE
                n["threads"][tid]["pc"] = "retire"
                acts.append((f"{tid}: [publish_before_store] store lands after publish", n))
            elif pc == "retire":
                n = clone(s)
                t = n["threads"][tid]
                if n["inflight"] == t["slot"]:
                    n["inflight"] = None
                t["pc"] = "done"
                acts.append((f"{tid}: leader removed the slot from the inflight map", n))
            elif pc == "poison":
                n = clone(s)
                t = n["threads"][tid]
                slot = n["slots"][t["slot"]]
                if self.mutation != "no_poison_on_death":
                    slot["state"] = "failed"
                    for w in slot["sleeping"]:
                        n["threads"][w]["pc"] = "consume"
                    slot["sleeping"] = []
                if n["inflight"] == t["slot"]:
                    n["inflight"] = None
                t["result"] = "DEAD"
                t["pc"] = "done"
                acts.append((f"{tid}: Drop guard poisons slot (Failed + notify) + retires it", n))
            elif pc == "wait":
                # One atomic step: predicate check + sleep, slot mutex held
                # across Condvar::wait — the no-lost-wakeup guarantee.
                n = clone(s)
                t = n["threads"][tid]
                slot = n["slots"][t["slot"]]
                if slot["state"] != "pending":
                    t["pc"] = "consume"
                    acts.append((f"{tid}: wait predicate already resolved — no sleep", n))
                else:
                    slot["sleeping"] = sorted(slot["sleeping"] + [tid])
                    t["pc"] = "sleeping"
                    acts.append((f"{tid}: slot Pending — waiter sleeps on the condvar", n))
            elif pc == "sleeping":
                pass  # only a notify can wake this thread
            elif pc == "consume":
                n = clone(s)
                t = n["threads"][tid]
                slot = n["slots"][t["slot"]]
                if slot["state"] == "done":
                    t["result"] = VALUE
                    t["pc"] = "done"
                    acts.append((f"{tid}: waiter woke to Done — took the value", n))
                elif slot["state"] == "failed":
                    t["pc"] = "fallback"
                    acts.append((f"{tid}: waiter woke to Failed — falling back", n))
                else:  # spurious-looking wake on Pending: loop back to wait
                    t["pc"] = "wait"
                    acts.append((f"{tid}: waiter woke to Pending — re-arming wait", n))
            elif pc == "fallback":
                n = clone(s)
                t = n["threads"][tid]
                if n["cache"] is not None:
                    t["result"] = n["cache"]
                    acts.append((f"{tid}: fallback found the cache warm", n))
                else:
                    n["computes"] += 1
                    n["cache"] = VALUE
                    t["result"] = VALUE
                    acts.append((f"{tid}: fallback computed locally (leader died)", n))
                t["pc"] = "done"
        return acts

    # -- invariants ----------------------------------------------------------

    def check(self, s):
        for slot in s["slots"]:
            if slot["state"] == "done" and s["cache"] is None:
                return (
                    "slot published Done while the cache is still empty — "
                    "store-before-publish violated (waiters may read a "
                    "non-durable value)"
                )
        live_leaders = [
            t for t, th in s["threads"].items()
            if th["role"] == "leader" and th["pc"] in
            ("recheck_cache", "compute", "store", "publish", "late_store")
        ]
        if len(live_leaders) > 1:
            return (
                f"two live leaders for one key ({', '.join(sorted(live_leaders))}) — "
                f"exactly-one-leader violated, the contraction will run twice"
            )
        return None

    def check_final(self, s):
        deaths = 1 - s["death_budget"]
        for tid, th in s["threads"].items():
            if th["pc"] != "done":
                return f"deadlock: {tid} stuck at pc `{th['pc']}` (slot never resolved?)"
            if th["result"] not in (VALUE, "DEAD"):
                return f"{tid} terminated with wrong value {th['result']!r}"
        if deaths == 0 and s["computes"] != 1:
            return (
                f"death-free run performed {s['computes']} contractions for one "
                f"key — coalescing must make it exactly one"
            )
        return None


def build(mutation=None):
    return CoalescerModel(mutation)
