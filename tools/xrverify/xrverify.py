#!/usr/bin/env python3
"""xrverify — exhaustive bounded model checking of the repo's concurrency
protocols (stdlib-only; runs in CI after xrlint, before the build).

The four hand-written protocols the service layer stands on — the
flock-guarded ProfileCache eviction, the Coalescer leader/waiter slots,
the WorkerPool fail-fast scheduler, and the crash-resumable job
registry — have never been executed in these containers (no cargo;
ROADMAP toolchain debt).  xrlint checks them syntactically; xrverify
checks the protocol DESIGNS semantically: each is a small transition
system (threads = step functions over explicit shared state,
nondeterminism = scheduler choice, crashes = environment actions), and
a breadth-first explorer with state hashing enumerates EVERY
interleaving up to a bounded configuration, checking safety invariants
in every reachable state and termination/liveness by backward
reachability from the acceptable terminal states.  A violation prints a
minimal-depth scheduler trace.

The models are digest-locked to the Rust they describe, the same way
xrlint's schemas.lock pins serialized layouts:

    // xrverify: model(<name>)
    ...protocol code the model transcribes...
    // xrverify: endmodel(<name>)

fences in the four source files are fingerprinted into
tools/xrverify/models.lock; editing fenced code without re-recording
(``--update-models-lock``, which you should only run together with a
model review) is finding V001, a missing/unbalanced fence is V002 —
so the Rust cannot silently diverge from the verified model.

Usage:
  xrverify.py [SRC_ROOT] [--models-lock PATH] [--update-models-lock]
              [--model NAME] [--mutate NAME:MUTATION] [--trace-dir DIR]
              [--list-mutations] [--skip-lock-check]

Exit 0 when clean, 1 on findings or an invariant violation, 2 on usage
errors.
"""

import hashlib
import os
import re
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import explorer  # noqa: E402
import model_cache  # noqa: E402
import model_coalescer  # noqa: E402
import model_pool  # noqa: E402
import model_registry  # noqa: E402

# model name -> (module, source file that must carry its fences)
MODELS = {
    "cache_eviction": (model_cache, "dse/cache.rs"),
    "coalescer": (model_coalescer, "dse/coalesce.rs"),
    "worker_pool": (model_pool, "runtime/pool.rs"),
    "job_registry": (model_registry, "service/jobs.rs"),
}

FENCE = re.compile(r"//\s*xrverify:\s*(model|endmodel)\((\w+)\)")


def fail(msg):
    print(f"xrverify error: {msg}", file=sys.stderr)
    sys.exit(2)


# --- fence fingerprinting ---------------------------------------------------

def extract_regions(src_root, rel):
    """{model name: [region text]} plus fence findings for one file."""
    path = os.path.join(src_root, rel)
    regions, findings = {}, []
    if not os.path.exists(path):
        return regions, [f"V002 {rel}: file not found under {src_root}"]
    with open(path, encoding="utf-8") as fh:
        lines = fh.read().split("\n")
    open_at = {}  # name -> (start line idx, [lines])
    for i, line in enumerate(lines):
        m = FENCE.search(line)
        if not m:
            for name in open_at:
                open_at[name][1].append(line)
            continue
        kind, name = m.group(1), m.group(2)
        if kind == "model":
            if name in open_at:
                findings.append(f"V002 {rel}:{i + 1} model({name}) fence reopened before endmodel")
            else:
                open_at[name] = (i, [])
        else:
            if name not in open_at:
                findings.append(f"V002 {rel}:{i + 1} endmodel({name}) without a matching model fence")
            else:
                _, body = open_at.pop(name)
                regions.setdefault(name, []).append("\n".join(body))
    for name, (i, _) in sorted(open_at.items()):
        findings.append(f"V002 {rel}:{i + 1} model({name}) fence never closed")
    return regions, findings


def fingerprint(src_root):
    """{model: (file, region count, line count, sha256 hex)} + findings."""
    prints, findings = {}, []
    for name, (_, rel) in sorted(MODELS.items()):
        regions, file_findings = extract_regions(src_root, rel)
        findings.extend(file_findings)
        body = regions.get(name)
        if not body:
            findings.append(
                f"V002 {rel}: no `// xrverify: model({name})` fence — the {name} "
                f"protocol must stay digest-locked to its verified model"
            )
            continue
        # Trailing whitespace is not semantics; everything else is.
        norm = "\n---\n".join("\n".join(l.rstrip() for l in r.split("\n")) for r in body)
        digest = hashlib.sha256(norm.encode("utf-8")).hexdigest()
        nlines = sum(r.count("\n") + 1 for r in body)
        prints[name] = (rel, len(body), nlines, digest)
        stray = sorted(set(regions) - set(MODELS))
        for s in stray:
            findings.append(f"V002 {rel}: fence model({s}) matches no registered model")
    return prints, findings


def parse_models_lock(path):
    locked = {}
    with open(path, encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            m = re.match(r"(\w+)\s+file=(\S+)\s+regions=(\d+)\s+lines=(\d+)\s+sha256=([0-9a-f]{64})", line)
            if not m:
                fail(f"{path}: unparseable models.lock line: {line}")
            locked[m.group(1)] = (m.group(2), int(m.group(3)), int(m.group(4)), m.group(5))
    return locked


def write_models_lock(path, prints):
    lines = [
        "# xrverify models.lock — fenced-region fingerprints per verified model.",
        "# A digest here asserts the fenced Rust still matches the transition",
        "# system tools/xrverify checks exhaustively. Regenerate ONLY together",
        "# with a review of the corresponding model_*.py:",
        "#   python3 tools/xrverify/xrverify.py --update-models-lock",
        "# (see DESIGN.md §3.8 for the fence/lock workflow)",
    ]
    for name in sorted(prints):
        rel, nregions, nlines, digest = prints[name]
        lines.append(f"{name} file={rel} regions={nregions} lines={nlines} sha256={digest}")
    with open(path, "w", encoding="utf-8") as fh:
        fh.write("\n".join(lines) + "\n")


def check_lock(src_root, lock_path, update):
    """Returns (findings, updated?)."""
    prints, findings = fingerprint(src_root)
    if update:
        if findings:
            return findings, False
        write_models_lock(lock_path, prints)
        for name in sorted(prints):
            rel, nregions, nlines, digest = prints[name]
            print(f"models.lock: recorded {name} ({rel}, {nregions} region(s), "
                  f"{nlines} lines, {digest[:16]}…)")
        return [], True
    if not os.path.exists(lock_path):
        findings.append(
            f"V003 models.lock not found at {lock_path}; run --update-models-lock "
            f"together with a model review to record the fenced regions"
        )
        return findings, False
    locked = parse_models_lock(lock_path)
    for name in sorted(prints):
        rel, nregions, nlines, digest = prints[name]
        if name not in locked:
            findings.append(
                f"V001 {rel}: model `{name}` is fenced but not in models.lock — "
                f"record it with --update-models-lock after reviewing model_*.py"
            )
            continue
        lrel, lregions, llines, ldigest = locked[name]
        if (rel, nregions, digest) != (lrel, lregions, ldigest):
            findings.append(
                f"V001 {rel}: fenced source for model `{name}` drifted from "
                f"models.lock (lock {ldigest[:16]}…/{llines} lines, code "
                f"{digest[:16]}…/{nlines} lines) — re-verify that "
                f"tools/xrverify/model_{_modfile(name)}.py still transcribes this "
                f"protocol, then re-record with --update-models-lock"
            )
    for name in sorted(set(locked) - set(prints)):
        findings.append(
            f"V003 models.lock records model `{name}` but no fenced region "
            f"provides it — stale entries must be removed with --update-models-lock"
        )
    return findings, False


def _modfile(name):
    return {"cache_eviction": "cache", "coalescer": "coalescer",
            "worker_pool": "pool", "job_registry": "registry"}.get(name, name)


# --- model runs -------------------------------------------------------------

def run_model(name, mutation, trace_dir):
    module, _ = MODELS[name]
    result = explorer.explore(module.build(mutation))
    tag = f"{name}" + (f" [mutation {mutation}]" if mutation else "")
    if result.ok:
        print(f"xrverify: model {tag}: OK — {result.states} states, "
              f"{result.transitions} transitions, {result.terminals} terminal(s), "
              f"every interleaving explored")
        return True
    text = result.violation.render(tag)
    print(text, file=sys.stderr)
    if trace_dir:
        os.makedirs(trace_dir, exist_ok=True)
        out = os.path.join(trace_dir, f"{name}{'.' + mutation if mutation else ''}.trace.txt")
        with open(out, "w", encoding="utf-8") as fh:
            fh.write(text + "\n")
        print(f"xrverify: counterexample trace written to {out}", file=sys.stderr)
    return False


def main():
    argv = sys.argv[1:]
    update = "--update-models-lock" in argv
    skip_lock = "--skip-lock-check" in argv
    list_mut = "--list-mutations" in argv
    argv = [a for a in argv if a not in
            ("--update-models-lock", "--skip-lock-check", "--list-mutations")]
    lock_path = trace_dir = only_model = mutate = None
    pos = []
    i = 0
    while i < len(argv):
        if argv[i] == "--models-lock":
            i += 1
            lock_path = argv[i] if i < len(argv) else fail("--models-lock needs a path")
        elif argv[i] == "--trace-dir":
            i += 1
            trace_dir = argv[i] if i < len(argv) else fail("--trace-dir needs a path")
        elif argv[i] == "--model":
            i += 1
            only_model = argv[i] if i < len(argv) else fail("--model needs a name")
        elif argv[i] == "--mutate":
            i += 1
            mutate = argv[i] if i < len(argv) else fail("--mutate needs NAME:MUTATION")
        elif argv[i].startswith("--"):
            fail(f"unknown option {argv[i]}")
        else:
            pos.append(argv[i])
        i += 1
    if len(pos) > 1:
        fail("usage: xrverify.py [SRC_ROOT] [--models-lock PATH] [--update-models-lock] "
             "[--model NAME] [--mutate NAME:MUTATION] [--trace-dir DIR] [--list-mutations]")

    here = os.path.dirname(os.path.abspath(__file__))
    src_root = pos[0] if pos else os.path.join(os.path.dirname(os.path.dirname(here)), "rust", "src")
    if lock_path is None:
        lock_path = os.path.join(here, "models.lock")
    if trace_dir is None:
        trace_dir = os.path.join(here, "traces")

    if list_mut:
        for name in sorted(MODELS):
            module, rel = MODELS[name]
            print(f"{name} ({rel}):")
            for mut, desc in sorted(module.MUTATIONS.items()):
                print(f"  {mut}: {desc}")
        return 0

    if mutate:
        if ":" not in mutate:
            fail("--mutate needs NAME:MUTATION (see --list-mutations)")
        name, mut = mutate.split(":", 1)
        if name not in MODELS:
            fail(f"unknown model {name!r} (known: {', '.join(sorted(MODELS))})")
        if mut not in MODELS[name][0].MUTATIONS:
            fail(f"unknown mutation {mut!r} for model {name} (see --list-mutations)")
        return 0 if run_model(name, mut, trace_dir) else 1

    if not os.path.isdir(src_root):
        fail(f"{src_root}: not a directory")

    findings = []
    if not skip_lock:
        findings, updated = check_lock(src_root, lock_path, update)
        if updated:
            print("xrverify: models.lock updated")
            return 0
    for f in findings:
        print(f, file=sys.stderr)

    names = [only_model] if only_model else sorted(MODELS)
    if only_model and only_model not in MODELS:
        fail(f"unknown model {only_model!r} (known: {', '.join(sorted(MODELS))})")
    ok = all([run_model(name, None, trace_dir) for name in names])

    if findings or not ok:
        print(f"xrverify: FAILED ({len(findings)} lock/fence finding(s), "
              f"models {'clean' if ok else 'VIOLATED'})", file=sys.stderr)
        return 1
    print(f"xrverify: OK — {len(names)} model(s) exhaustively explored, "
          f"models.lock digests match the fenced Rust")
    return 0


if __name__ == "__main__":
    sys.exit(main())
