#!/usr/bin/env python3
"""Smoke regression gate over the bench JSON artifacts.

Parses BENCH_sweep.json and BENCH_search.json (written by
`cargo bench --bench bench_sweep_parallel` / `--bench bench_search`,
quick mode in CI) and fails the job when an optimized path loses its
advantage:

* sweep — a wall-clock sanity check: the two-phase (profile-once +
  overlay) sweep must not be slower than the fused per-scenario fan-out
  at the same thread count. The structural engine-work ratio is
  N_scenarios : 1 (9:1 on this grid), so the gate allows a generous
  noise margin for the few-sample quick mode and only fails below
  0.8x — a genuine regression collapses the ratio to ~1/N, far past
  the margin; runner jitter does not.
* search — evaluation-count checks (deterministic, no timing noise):
  `search/evaluations_vs_exhaustive` must be >= 121/72 ~ 1.67x (the
  <= 60% anchor budget locked by the e2e tests; a search that degrades
  toward exhaustive enumeration fails here first), and
  `search/expanded_coverage` must be >= 5x (the expanded-space search
  must converge well under 20% coverage; observed ~2%).
* cache — `cache/warm_contractions_avoided` must be >= 1.0x (hits /
  profile chunks of a warm sweep over a fully cached space: every
  phase-A engine contraction must be served from disk; any value below
  1.0 means the cache failed to round-trip at least one chunk). Also a
  deterministic counter check, immune to runner jitter. And
  `cache/warm_read_speedup` must be >= 2.0x: the binary-sidecar warm
  read must keep a decisive decode advantage over the JSON envelope
  (observed well above 2x; the floor is the noise-shielded minimum the
  raw-bits format must never lose).
* trace — `trace/warm_contractions_avoided` must be >= 1.0x (hits /
  profile chunks of a warm sweep whose scenarios carry a 24-segment
  diurnal trace): the trace axis multiplies phase-B overlays, never
  phase-A profiling, so every contraction must still come from the
  cache regardless of segment fan-out. Deterministic counter check.
* hotloop — the three PR 7 optimizations, each measured against the
  exact code it replaced (same inputs, bit-identical outputs):
  `hotloop/vector_speedup` (lane-blocked phase-A kernel vs the scalar
  oracle), `hotloop/overlay_batch_speedup` (one apply_batch pass vs
  per-overlay apply) and `hotloop/pool_speedup` (persistent worker
  pool vs per-call scoped spawn). Each must stay >= 1.0x: an optimized
  path that loses to its own baseline is a regression, full stop;
  observed margins are comfortably above the floor, so quick-mode
  jitter does not graze it.
* service — `service/coalesced_contractions_avoided` must be >= 1.0x
  (duplicate phase-A contractions avoided by N identical concurrent
  sweep clients sharing one cache + coalescer, over the ideal
  (N-1)*chunks). The ratio is an exact counter identity — each unique
  chunk is contracted exactly once across all clients — so anything
  below 1.0 means a duplicate contraction slipped through the
  coalescer. Deterministic, immune to runner jitter.

Usage: check_bench_gate.py BENCH_sweep.json BENCH_search.json \\
       BENCH_cache.json BENCH_trace.json BENCH_hotloop.json \\
       BENCH_service.json
"""
import json
import sys

# Wall-clock margin for the sweep comparison (quick-mode noise shield).
SWEEP_MIN_RATIO = 0.8
# The e2e-locked <= 60% anchor budget, as an evaluations-saved ratio.
SEARCH_ANCHOR_MIN = 1.0 / 0.6
# Expanded space must stay under 20% coverage (observed ~2%).
SEARCH_EXPANDED_MIN = 5.0
# A warm sweep must avoid every phase-A contraction (hits == chunks).
CACHE_WARM_MIN = 1.0
# Binary sidecar warm reads must beat JSON envelope parses by >= 2x.
CACHE_BINARY_READ_MIN = 2.0
# A warm trace sweep must still avoid every phase-A contraction: the
# trace fan-out is phase-B-only work.
TRACE_WARM_MIN = 1.0
# Optimized hot-loop paths must never lose to their own baselines.
HOTLOOP_MINS = {
    "hotloop/vector_speedup": 1.0,
    "hotloop/overlay_batch_speedup": 1.0,
    "hotloop/pool_speedup": 1.0,
}
# N coalesced clients must contract each unique chunk exactly once.
SERVICE_COALESCE_MIN = 1.0


def fail(msg):
    print(f"BENCH GATE FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def load(path):
    try:
        with open(path) as f:
            rows = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"cannot read {path}: {e}")
    return {r["name"]: r for r in rows}


def check_sweep(path):
    rows = load(path)
    checked = 0
    for name, row in sorted(rows.items()):
        if not name.startswith("sweep/fused_per_scenario_threads="):
            continue
        threads = name.rsplit("=", 1)[1]
        two = rows.get(f"sweep/two_phase_threads={threads}")
        if two is None:
            continue
        ratio = row["mean_ns"] / max(two["mean_ns"], 1)
        print(f"sweep gate: fused/two-phase @ {threads} thread(s) = {ratio:.2f}x")
        if ratio < SWEEP_MIN_RATIO:
            fail(
                f"two-phase sweep slower than fused at {threads} thread(s) "
                f"({ratio:.2f}x < {SWEEP_MIN_RATIO}x)"
            )
        checked += 1
    if checked == 0:
        fail(f"{path}: no fused/two-phase pair found")


def check_search(path):
    rows = load(path)
    for name, minimum in (
        ("search/evaluations_vs_exhaustive", SEARCH_ANCHOR_MIN),
        ("search/expanded_coverage", SEARCH_EXPANDED_MIN),
    ):
        row = rows.get(name)
        if row is None:
            fail(f"{path}: missing entry {name}")
        ratio = row.get("throughput")
        if ratio is None:
            fail(f"{path}: {name} has no ratio")
        print(
            f"search gate: {name} = {ratio:.2f}x "
            f"(min {minimum:.2f}x, {row['samples']} evaluations)"
        )
        if ratio < minimum:
            fail(f"{name} reports {ratio:.2f}x < {minimum:.2f}x evaluations-saved")


def check_cache(path):
    rows = load(path)
    name = "cache/warm_contractions_avoided"
    row = rows.get(name)
    if row is None:
        fail(f"{path}: missing entry {name}")
    ratio = row.get("throughput")
    if ratio is None:
        fail(f"{path}: {name} has no ratio")
    print(
        f"cache gate: {name} = {ratio:.2f}x "
        f"(min {CACHE_WARM_MIN:.2f}x, {row['samples']} contraction(s) avoided)"
    )
    if row["samples"] < 1:
        fail(f"{name}: warm sweep avoided zero contractions")
    if ratio < CACHE_WARM_MIN:
        fail(
            f"{name} reports {ratio:.2f}x < {CACHE_WARM_MIN:.2f}x — a warm sweep "
            f"re-contracted at least one cached chunk"
        )
    name = "cache/warm_read_speedup"
    row = rows.get(name)
    if row is None:
        fail(f"{path}: missing entry {name}")
    speedup = row.get("throughput")
    if speedup is None:
        fail(f"{path}: {name} has no ratio")
    print(
        f"cache gate: {name} = {speedup:.2f}x (min {CACHE_BINARY_READ_MIN:.2f}x)"
    )
    if speedup < CACHE_BINARY_READ_MIN:
        fail(
            f"{name} reports {speedup:.2f}x < {CACHE_BINARY_READ_MIN:.2f}x — the binary "
            f"sidecar lost its warm-read advantage over the JSON envelope"
        )


def check_trace(path):
    rows = load(path)
    name = "trace/warm_contractions_avoided"
    row = rows.get(name)
    if row is None:
        fail(f"{path}: missing entry {name}")
    ratio = row.get("throughput")
    if ratio is None:
        fail(f"{path}: {name} has no ratio")
    print(
        f"trace gate: {name} = {ratio:.2f}x "
        f"(min {TRACE_WARM_MIN:.2f}x, {row['samples']} contraction(s) avoided)"
    )
    if row["samples"] < 1:
        fail(f"{name}: warm trace sweep avoided zero contractions")
    if ratio < TRACE_WARM_MIN:
        fail(
            f"{name} reports {ratio:.2f}x < {TRACE_WARM_MIN:.2f}x — the trace fan-out "
            f"re-contracted at least one cached chunk (segments must be phase-B-only)"
        )


def check_hotloop(path):
    rows = load(path)
    for name, minimum in sorted(HOTLOOP_MINS.items()):
        row = rows.get(name)
        if row is None:
            fail(f"{path}: missing entry {name}")
        ratio = row.get("throughput")
        if ratio is None:
            fail(f"{path}: {name} has no ratio")
        print(f"hotloop gate: {name} = {ratio:.2f}x (min {minimum:.2f}x)")
        if ratio < minimum:
            fail(
                f"{name} reports {ratio:.2f}x < {minimum:.2f}x — the optimized "
                f"path lost to the baseline it replaced"
            )


def check_service(path):
    rows = load(path)
    name = "service/coalesced_contractions_avoided"
    row = rows.get(name)
    if row is None:
        fail(f"{path}: missing entry {name}")
    ratio = row.get("throughput")
    if ratio is None:
        fail(f"{path}: {name} has no ratio")
    print(
        f"service gate: {name} = {ratio:.2f}x "
        f"(min {SERVICE_COALESCE_MIN:.2f}x, {row['samples']} contraction(s) avoided)"
    )
    if row["samples"] < 1:
        fail(f"{name}: concurrent clients avoided zero duplicate contractions")
    if ratio < SERVICE_COALESCE_MIN:
        fail(
            f"{name} reports {ratio:.2f}x < {SERVICE_COALESCE_MIN:.2f}x — a duplicate "
            f"phase-A contraction slipped through the request coalescer"
        )


def main():
    if len(sys.argv) != 7:
        fail(
            "usage: check_bench_gate.py BENCH_sweep.json BENCH_search.json "
            "BENCH_cache.json BENCH_trace.json BENCH_hotloop.json BENCH_service.json"
        )
    check_sweep(sys.argv[1])
    check_search(sys.argv[2])
    check_cache(sys.argv[3])
    check_trace(sys.argv[4])
    check_hotloop(sys.argv[5])
    check_service(sys.argv[6])
    print("bench gate: OK")


if __name__ == "__main__":
    main()
