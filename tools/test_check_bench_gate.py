#!/usr/bin/env python3
"""Self-test for check_bench_gate.py on synthetic artifacts.

Builds a healthy set of the six BENCH_*.json files in a temp directory,
asserts the gate passes, then breaks one artifact at a time and asserts
the gate fails with a message naming the broken metric. No cargo run
needed — this locks the gate's *logic* (row lookup, ratio floors,
sample floors, argv handling) so a gate edit can't silently stop
guarding a metric.

Usage: python3 tools/test_check_bench_gate.py
"""
import json
import os
import subprocess
import sys
import tempfile

GATE = os.path.join(os.path.dirname(os.path.abspath(__file__)), "check_bench_gate.py")


def row(name, samples=8, mean_ns=1_000_000, throughput=None):
    return {
        "name": name,
        "samples": samples,
        "mean_ns": mean_ns,
        "p50_ns": mean_ns,
        "p95_ns": mean_ns,
        "throughput": throughput,
    }


def healthy():
    """A full artifact set that clears every floor with margin."""
    return {
        "BENCH_sweep.json": [
            row("sweep/fused_per_scenario_threads=1", mean_ns=9_000_000),
            row("sweep/two_phase_threads=1", mean_ns=1_000_000),
            row("sweep/fused_per_scenario_threads=4", mean_ns=3_000_000),
            row("sweep/two_phase_threads=4", mean_ns=500_000),
        ],
        "BENCH_search.json": [
            row("search/evaluations_vs_exhaustive", samples=68, throughput=121 / 68),
            row("search/expanded_coverage", samples=200, throughput=51.0),
        ],
        "BENCH_cache.json": [
            row("cache/warm_contractions_avoided", samples=9, throughput=1.0),
            row("cache/warm_read_speedup", samples=20, throughput=6.5),
        ],
        "BENCH_trace.json": [
            row("trace/warm_contractions_avoided", samples=9, throughput=1.0),
        ],
        "BENCH_hotloop.json": [
            row("hotloop/vector_speedup", throughput=2.4),
            row("hotloop/overlay_batch_speedup", throughput=1.8),
            row("hotloop/pool_speedup", throughput=1.3),
        ],
        "BENCH_service.json": [
            row("service/concurrent_sweeps_x4_coalesced"),
            row("service/concurrent_sweeps_x4_uncoalesced"),
            row("service/coalesced_contractions_avoided", samples=9, throughput=1.0),
            row("service/uncoalesced_duplicate_contractions", samples=6, throughput=3.0),
        ],
    }


ORDER = [
    "BENCH_sweep.json",
    "BENCH_search.json",
    "BENCH_cache.json",
    "BENCH_trace.json",
    "BENCH_hotloop.json",
    "BENCH_service.json",
]


def run_gate(tmp, artifacts):
    for fname, rows in artifacts.items():
        with open(os.path.join(tmp, fname), "w") as f:
            json.dump(rows, f)
    return subprocess.run(
        [sys.executable, GATE] + [os.path.join(tmp, f) for f in ORDER],
        capture_output=True,
        text=True,
    )


def expect_pass(tmp, artifacts, label):
    r = run_gate(tmp, artifacts)
    assert r.returncode == 0, f"{label}: expected pass, got:\n{r.stdout}{r.stderr}"
    assert "bench gate: OK" in r.stdout, f"{label}: no OK line:\n{r.stdout}"
    print(f"  pass: {label}")


def expect_fail(tmp, artifacts, needle, label):
    r = run_gate(tmp, artifacts)
    assert r.returncode != 0, f"{label}: expected failure, gate passed:\n{r.stdout}"
    assert "BENCH GATE FAIL" in r.stderr, f"{label}: no FAIL banner:\n{r.stderr}"
    assert needle in r.stderr, f"{label}: stderr lacks {needle!r}:\n{r.stderr}"
    print(f"  fail as expected: {label}")


def mutate(base, fname, match, **changes):
    """Copy the artifact set, editing the matching row's fields."""
    out = {k: [dict(r) for r in v] for k, v in base.items()}
    hit = [r for r in out[fname] if r["name"] == match]
    assert hit, f"no row {match} in {fname}"
    hit[0].update(changes)
    return out


def drop(base, fname, match):
    out = {k: [dict(r) for r in v] for k, v in base.items()}
    out[fname] = [r for r in out[fname] if r["name"] != match]
    return out


def main():
    base = healthy()
    with tempfile.TemporaryDirectory() as tmp:
        expect_pass(tmp, base, "healthy artifact set")

        # Boundary values sit exactly on their floors — still a pass.
        boundary = mutate(
            base, "BENCH_sweep.json", "sweep/fused_per_scenario_threads=1", mean_ns=800_000
        )
        boundary = mutate(
            boundary, "BENCH_service.json", "service/coalesced_contractions_avoided",
            samples=1, throughput=1.0,
        )
        expect_pass(tmp, boundary, "every ratio exactly at its floor")

        expect_fail(
            tmp,
            mutate(base, "BENCH_sweep.json", "sweep/fused_per_scenario_threads=1",
                   mean_ns=700_000),
            "two-phase sweep slower than fused",
            "sweep regression below 0.8x",
        )
        expect_fail(
            tmp,
            drop(drop(base, "BENCH_sweep.json", "sweep/two_phase_threads=1"),
                 "BENCH_sweep.json", "sweep/two_phase_threads=4"),
            "no fused/two-phase pair",
            "sweep artifact with no comparable pair",
        )
        expect_fail(
            tmp,
            mutate(base, "BENCH_search.json", "search/evaluations_vs_exhaustive",
                   throughput=1.2),
            "search/evaluations_vs_exhaustive",
            "search over the anchor budget",
        )
        expect_fail(
            tmp,
            mutate(base, "BENCH_cache.json", "cache/warm_contractions_avoided",
                   throughput=0.89),
            "re-contracted at least one cached chunk",
            "warm cache miss",
        )
        expect_fail(
            tmp,
            mutate(base, "BENCH_cache.json", "cache/warm_read_speedup", throughput=1.4),
            "warm-read advantage",
            "binary sidecar losing to JSON",
        )
        expect_fail(
            tmp,
            mutate(base, "BENCH_trace.json", "trace/warm_contractions_avoided", samples=0,
                   throughput=0.0),
            "avoided zero contractions",
            "trace warm sweep with zero hits",
        )
        expect_fail(
            tmp,
            mutate(base, "BENCH_hotloop.json", "hotloop/pool_speedup", throughput=0.93),
            "hotloop/pool_speedup",
            "hotloop optimization losing to its baseline",
        )
        expect_fail(
            tmp,
            mutate(base, "BENCH_service.json", "service/coalesced_contractions_avoided",
                   throughput=0.92),
            "slipped through the request coalescer",
            "duplicate contraction under coalescing",
        )
        expect_fail(
            tmp,
            mutate(base, "BENCH_service.json", "service/coalesced_contractions_avoided",
                   samples=0, throughput=0.0),
            "avoided zero duplicate contractions",
            "coalescer avoiding nothing",
        )
        expect_fail(
            tmp,
            drop(base, "BENCH_service.json", "service/coalesced_contractions_avoided"),
            "missing entry service/coalesced_contractions_avoided",
            "service artifact missing its counter row",
        )
        expect_fail(
            tmp,
            mutate(base, "BENCH_service.json", "service/coalesced_contractions_avoided",
                   throughput=None),
            "has no ratio",
            "service counter row without a ratio",
        )

        # argv handling: the gate takes exactly six artifacts.
        short = subprocess.run(
            [sys.executable, GATE, os.path.join(tmp, "BENCH_sweep.json")],
            capture_output=True,
            text=True,
        )
        assert short.returncode != 0 and "usage:" in short.stderr, short.stderr
        print("  fail as expected: wrong artifact count")

        missing = dict(base)
        missing.pop("BENCH_service.json")
        for f in list(os.listdir(tmp)):
            os.remove(os.path.join(tmp, f))
        expect_fail(tmp, missing, "cannot read", "unreadable artifact")

    print("gate self-test: OK")


if __name__ == "__main__":
    main()
