// BAD: field set changed without a version bump (S001) and a trailer is
// appended after splice_digest sealed the body (S003).
pub const PROFILE_SCHEMA: u32 = 1;

pub fn to_json_string(a: f32, b: f32, c: f32) -> String {
    let body = Json::obj(vec![
        ("alpha", Json::Num(a as f64)),
        ("bravo", Json::Num(b as f64)),
        ("charlie", Json::Num(c as f64)),
    ])
    .to_string();
    let mut out = splice_digest(&body);
    out.push_str(",\"trailer\":1");
    out
}
