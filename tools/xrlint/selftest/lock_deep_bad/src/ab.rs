// BAD: the cycle only appears three calls deep. `front` holds `a` and
// calls `mid_b`, which calls `leaf_b`, which takes `b`; `back` holds
// `b` and calls `mid_a` -> `leaf_a`, which takes `a`. One-level callee
// summaries saw no locks on `mid_b`/`mid_a` and missed both edges; the
// interprocedural fixpoint closes the chain and reports L001.
impl Pair {
    fn leaf_b(&self) {
        let g = self.b.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        drop(g);
    }

    fn mid_b(&self) {
        self.leaf_b();
    }

    fn front(&self) {
        let g = self.a.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        self.mid_b();
        drop(g);
    }

    fn leaf_a(&self) {
        let g = self.a.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        drop(g);
    }

    fn mid_a(&self) {
        self.leaf_a();
    }

    fn back(&self) {
        let g = self.b.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        self.mid_a();
        drop(g);
    }
}
