// GOOD: the kernel is fenced, and the only fold runs over a slice
// iterator whose order is fixed.
// xrlint: region(bit-identical)
fn apply(xs: &[f32]) -> f32 {
    xs.iter().sum()
}
// xrlint: endregion(bit-identical)
