// GOOD: render under the lock, write after it is released.
impl Registry {
    fn persist(&self) {
        let text = {
            let st = self.state.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            st.render()
        };
        std::fs::write("spec.json", text).ok();
    }
}
