// GOOD: both paths take a before b — the acquired-while-held graph is
// a → b, acyclic.
impl Pair {
    fn one(&self) {
        let g1 = self.a.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let g2 = self.b.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        drop(g2);
        drop(g1);
    }

    fn two(&self) {
        let g1 = self.a.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let g2 = self.b.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        drop(g2);
        drop(g1);
    }
}
