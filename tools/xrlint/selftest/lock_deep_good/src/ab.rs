// GOOD: both outer functions hold `a` while reaching `b` through the
// same two-call chain — every transitive edge is a -> b, acyclic. This
// guards the fixpoint against manufacturing false edges out of deep
// `self.` call chains.
impl Pair {
    fn leaf_b(&self) {
        let g = self.b.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        drop(g);
    }

    fn mid_b(&self) {
        self.leaf_b();
    }

    fn front(&self) {
        let g = self.a.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        self.mid_b();
        drop(g);
    }

    fn back(&self) {
        let g = self.a.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        self.mid_b();
        drop(g);
    }
}
