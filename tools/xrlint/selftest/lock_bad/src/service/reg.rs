// BAD: filesystem I/O while holding the registry lock (L002) — disk
// latency rides on the lock every status poll contends on.
impl Registry {
    fn persist(&self) {
        let st = self.state.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        std::fs::write("spec.json", st.render()).ok();
    }
}
