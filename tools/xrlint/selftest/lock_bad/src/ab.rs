// BAD: `one` takes a then b, `two` takes b then a — a cycle in the
// acquired-while-held graph (L001).
impl Pair {
    fn one(&self) {
        let g1 = self.a.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let g2 = self.b.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        drop(g2);
        drop(g1);
    }

    fn two(&self) {
        let g2 = self.b.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let g1 = self.a.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        drop(g1);
        drop(g2);
    }
}
