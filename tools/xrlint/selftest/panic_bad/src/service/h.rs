// BAD: a bare unwrap on a service request path (P001) — a malformed
// request would kill the worker thread instead of returning a 400.
fn parse_len(s: &str) -> usize {
    s.trim().parse().unwrap()
}
