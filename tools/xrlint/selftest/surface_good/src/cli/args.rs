const VALUED: &[&str] = &["alpha"];
const FLAGS: &[&str] = &["beta"];
