const USAGE: &str = "usage: tool --alpha N [--beta]";

fn main() {}
