pub fn handle_request(method: &str, path: &str) -> u16 {
    match (method, path) {
        ("POST", "/v1/sweep") => 200,
        ("GET", "/v1/stats") => 200,
        _ => 404,
    }
}
