// GOOD: the one justified panic carries an allow with a reason; the
// fallible parse returns an error to the caller.
fn parse_len(s: &str) -> Result<usize, String> {
    s.trim().parse().map_err(|_| "invalid content-length".to_string())
}

fn first_byte(buf: &[u8]) -> u8 {
    // xrlint: allow(panic, "caller checked is_empty one line above")
    buf[0]
}
