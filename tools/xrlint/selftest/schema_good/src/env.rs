// GOOD: the rendered field set matches schemas.lock and nothing touches
// the body after splice_digest seals it.
pub const PROFILE_SCHEMA: u32 = 1;

pub fn to_json_string(a: f32, b: f32, c: f32) -> String {
    let body = Json::obj(vec![
        ("alpha", Json::Num(a as f64)),
        ("bravo", Json::Num(b as f64)),
        ("charlie", Json::Num(c as f64)),
    ])
    .to_string();
    splice_digest(&body)
}
