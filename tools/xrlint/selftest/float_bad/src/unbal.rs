// BAD: endregion with no opening fence (R001).
fn noop() {}
// xrlint: endregion(bit-identical)
