// BAD: carbon/overlay.rs must fence its kernel in a bit-identical
// region; this copy carries none (R002).
fn apply(xs: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for &x in xs {
        acc += x;
    }
    acc
}
