// BAD: every float-determinism hazard inside one bit-identical fence —
// an unordered HashMap fold (F001 + F002), a fused mul_add (F003) and a
// thread spawn (F004).
use std::collections::HashMap;

// xrlint: region(bit-identical)
fn total(m: &HashMap<u32, f32>) -> f32 {
    let s: f32 = m.values().sum();
    let t = 1.0f32.mul_add(2.0, s);
    std::thread::spawn(|| {});
    t
}
// xrlint: endregion(bit-identical)
