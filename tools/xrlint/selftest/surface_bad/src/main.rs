const USAGE: &str = "usage: tool --alpha N --gamma";

fn main() {}
