// BAD: `--beta` is registered but undocumented; USAGE sells `--gamma`
// which the parser rejects (C001 both directions).
const VALUED: &[&str] = &["alpha"];
const FLAGS: &[&str] = &["beta"];
