// BAD: `/v1/extra` is served but missing from the DESIGN.md §3.6 table,
// and the documented `/v1/stats` is not routed (C002 both directions).
pub fn handle_request(method: &str, path: &str) -> u16 {
    match (method, path) {
        ("POST", "/v1/sweep") => 200,
        ("GET", "/v1/extra") => 200,
        _ => 404,
    }
}
