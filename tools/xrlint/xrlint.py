#!/usr/bin/env python3
"""xrlint — repo-invariant static analysis over rust/src (stdlib-only).

The bit-identity, persistence and concurrency guarantees this repo makes
(DESIGN.md §3.3–§3.6) are invariants the type system cannot see: digest
coverage of serialized envelopes, schema-version bumps on layout change,
a fixed f32 fold order in the bit-identical kernels, a cycle-free lock
acquisition order, and panic-free service/pool request paths. No cargo
toolchain exists in the growth containers (ROADMAP), so this analyzer is
the verification layer that actually executes there — and it runs in CI
before the build.

Rule families (each suppressible, see DESIGN.md §3.7):

* S — schema/digest drift. Every `const *_SCHEMA: u32` file's rendered
  field set is fingerprinted into `schemas.lock`; changing the fields
  without bumping the version (S001), diverging from the lock (S002) or
  appending to a body *after* `splice_digest` sealed it (S003) fails.
* F/R — float determinism. Inside `// xrlint: region(bit-identical)`
  fences: unordered f32 folds (F001), unordered containers (F002),
  `mul_add` contraction (F003), thread spawns (F004). Unbalanced fences
  are R001; deleting a fence from a file that must carry one is R002.
* L — lock order. Extracts Mutex/flock acquisition sites, builds the
  acquired-while-held graph (interprocedural: per-function acquisition
  summaries are closed over the call graph to a fixpoint, so a lock
  taken three calls deep still contributes edges at every transitive
  caller; the call graph covers free/path/`self.` calls resolved
  same-file first, else to a globally unique definition — receiver-
  dispatched method names and ambiguous cross-file names are excluded
  as unresolvable), fails on cycles (L001) and on filesystem I/O
  performed while the service registry lock is held (L002).
* P — panic paths. `unwrap`/`expect`/`panic!`/indexing in `service/`
  and `runtime/pool.rs` must carry `// xrlint: allow(panic, "why")`.
* C — surface consistency. CLI options registered in `cli/args.rs` vs
  the `USAGE` text in `main.rs` (C001); routes in `service/http.rs` vs
  the DESIGN.md §3.6 endpoint table (C002).

Suppression: `// xrlint: allow(<family>[, "reason"])` on the finding's
line or the line above (family ∈ schema|float|lock|panic|surface; panic
requires a non-empty reason). A baseline file (default
tools/xrlint/baseline.txt, `RULE|path-substring|message-substring` per
line) suppresses legacy findings wholesale. Baseline entries are debt,
not configuration: an entry that suppressed nothing over a whole run is
stale and becomes a B001 finding itself, so fixed debt cannot silently
keep a suppression hole open; `--prune-baseline` rewrites the file with
the stale entries removed.

Usage:
  xrlint.py SRC_ROOT [--schemas-lock PATH] [--baseline PATH]
            [--update-schemas-lock] [--prune-baseline]

Exit 0 when clean, 1 on findings, 2 on usage/internal errors.
"""

import os
import re
import sys

# --- configuration ---------------------------------------------------------

# Files that must carry at least this many region(bit-identical) fences
# when present under the scanned root: the kernels and combiners whose
# f32 operation order is the repo's bit-identity contract.
REQUIRED_REGIONS = {
    "carbon/overlay.rs": 1,
    "carbon/trace.rs": 1,
    "runtime/host.rs": 1,
    "dse/sweep.rs": 1,
}

# Canonical lock names: (path suffix or prefix fragment, receiver ident)
# -> name. Fallback is "<file stem>.<ident>".
LOCK_ALIASES = [
    ("service/", "state", "service.registry"),
    ("dse/coalesce.rs", "inflight", "coalesce.inflight"),
    ("dse/coalesce.rs", "slot", "coalesce.slot"),
    ("dse/coalesce.rs", "lock", "coalesce.slot"),
    ("dse/cache.rs", "mem", "cache.mem"),
    ("dse/cache.rs", "disk", "cache.disk"),
    ("dse/cache.rs", "f", "cache.flock"),
    ("dse/cache.rs", "file", "cache.flock"),
    ("runtime/pool.rs", "jobs", "pool.jobs"),
]

# Locks under which no filesystem I/O may run (they sit on every poll
# path; DESIGN.md §3.7 lock-order contract).
NO_IO_LOCKS = {"service.registry"}

IO_TOKENS = re.compile(
    r"\batomic_write(?:_bytes)?\s*\(|\bstd::fs::|\bread_to_string\s*\(|"
    r"\bFile::|\bOpenOptions\b|\bwrite_all\s*\(|\bremove_file\s*\(|"
    r"\bcreate_dir"
)

# Slice-backed (deterministically ordered) iterator sources that make a
# same-statement `.sum()` / `.fold(` acceptable inside a region.
ORDERED_ITER = re.compile(r"\.iter\(\)|\.iter_mut\(\)|\.chunks|\.windows|\.enumerate\(\)")

FAMILY_OF = {"S": "schema", "F": "float", "R": "float", "L": "lock", "P": "panic", "C": "surface"}


def fail(msg):
    print(f"xrlint error: {msg}", file=sys.stderr)
    sys.exit(2)


# --- source model ----------------------------------------------------------

DIRECTIVE = re.compile(r"//\s*xrlint:\s*(allow|region|endregion)\((.*)\)")


class SourceFile:
    """One .rs file: raw text plus comment/string-stripped views and the
    parsed `// xrlint:` directives. Line counts are preserved across the
    stripped views so findings carry real line numbers."""

    def __init__(self, root, rel):
        self.rel = rel
        self.path = os.path.join(root, rel)
        with open(self.path, encoding="utf-8") as fh:
            self.raw = fh.read()
        self.raw_lines = self.raw.split("\n")
        # code_ws: comments removed, strings kept (field/route/option
        # extraction). code_ns: comments AND string contents removed
        # (token analysis that must not trip on words inside strings).
        self.code_ws = _strip(self.raw, keep_strings=True).split("\n")
        self.code_ns = _strip(self.raw, keep_strings=False).split("\n")
        # Everything from the first `#[cfg(test)]` on is test scaffolding
        # (the repo convention puts test modules at file end).
        self.test_start = len(self.raw_lines)
        for i, line in enumerate(self.raw_lines):
            if "#[cfg(test)]" in line:
                self.test_start = i
                break
        self.directives = {}  # line index -> (kind, args)
        for i, line in enumerate(self.raw_lines):
            m = DIRECTIVE.search(line)
            if m:
                self.directives[i] = (m.group(1), m.group(2).strip())

    def code_text(self, strings=True, tests=False):
        lines = self.code_ws if strings else self.code_ns
        end = len(lines) if tests else self.test_start
        return "\n".join(lines[:end])

    def allow_on(self, line_idx, family):
        """True when an allow(<family>) directive sits on this line or
        the one above (0-based index)."""
        for i in (line_idx, line_idx - 1):
            if i in self.directives:
                kind, args = self.directives[i]
                if kind == "allow" and args.split(",")[0].strip() == family:
                    return True
        return False

    def allow_reason(self, line_idx, family):
        """The quoted reason of a matching allow, or None."""
        for i in (line_idx, line_idx - 1):
            if i in self.directives:
                kind, args = self.directives[i]
                if kind == "allow" and args.split(",")[0].strip() == family:
                    m = re.search(r'"([^"]*)"', args)
                    return m.group(1) if m else ""
        return None


def _strip(text, keep_strings):
    """Strip comments (line + block) and optionally string/char literal
    contents, preserving newlines so line numbers survive."""
    out = []
    i, n = 0, len(text)
    while i < n:
        c = text[i]
        if c == "/" and i + 1 < n and text[i + 1] == "/":
            while i < n and text[i] != "\n":
                i += 1
            continue
        if c == "/" and i + 1 < n and text[i + 1] == "*":
            depth = 1
            i += 2
            while i < n and depth:
                if text[i] == "/" and i + 1 < n and text[i + 1] == "*":
                    depth += 1
                    i += 2
                elif text[i] == "*" and i + 1 < n and text[i + 1] == "/":
                    depth -= 1
                    i += 2
                else:
                    if text[i] == "\n":
                        out.append("\n")
                    i += 1
            continue
        if c == '"' or (c in "br" and _string_ahead(text, i)):
            j, literal = _scan_string(text, i)
            if keep_strings:
                out.append(literal)
            else:
                out.append('""')
                out.extend("\n" for ch in literal if ch == "\n")
            i = j
            continue
        if c == "'" and i + 2 < n:
            # Char literal ('x' / '\n'); lifetimes ('a>) fall through.
            if text[i + 1] == "\\" and i + 3 < n and text[i + 3] == "'":
                out.append("' '" if not keep_strings else text[i : i + 4])
                i += 4
                continue
            if text[i + 1] != "\\" and text[i + 2] == "'":
                out.append("' '" if not keep_strings else text[i : i + 3])
                i += 3
                continue
        out.append(c)
        i += 1
    return "".join(out)


def _string_ahead(text, i):
    """At `b"..."`, `r"..."` or `br"..."`/`r#"..."#` openers."""
    m = re.match(r'(?:b?r#*|b)"', text[i:])
    return bool(m) and (i == 0 or not (text[i - 1].isalnum() or text[i - 1] == "_"))


def _scan_string(text, i):
    """Consume a string literal starting at i; returns (end, literal)."""
    m = re.match(r'(b?r(#*))"', text[i:])
    if m:  # raw string: ends at "#...# with matching hash count
        hashes = m.group(2)
        start = i
        i += m.end()
        end_marker = '"' + hashes
        j = text.find(end_marker, i)
        j = len(text) if j < 0 else j + len(end_marker)
        return j, text[start:j]
    start = i
    i += 2 if text[i] == "b" else 1  # opening quote (skip b prefix)
    n = len(text)
    while i < n:
        if text[i] == "\\":
            i += 2
            continue
        if text[i] == '"':
            i += 1
            break
        i += 1
    return i, text[start:i]


def function_spans(sf):
    """[(name, start_line, end_line)] per `fn` in non-test code, by brace
    matching on the string-stripped view."""
    text = sf.code_text(strings=False)
    spans = []
    for m in re.finditer(r"(?:^|[\s>])fn\s+(\w+)", text):
        name = m.group(1)
        brace = text.find("{", m.end())
        semi = text.find(";", m.end())
        if brace < 0 or (0 <= semi < brace):  # trait signature, no body
            continue
        depth, i = 0, brace
        while i < len(text):
            if text[i] == "{":
                depth += 1
            elif text[i] == "}":
                depth -= 1
                if depth == 0:
                    break
            i += 1
        start = text.count("\n", 0, m.start()) + 1
        end = text.count("\n", 0, min(i, len(text) - 1)) + 1
        spans.append((name, start, end))
    return spans


# --- findings --------------------------------------------------------------

class Findings:
    def __init__(self, baseline):
        self.rows = []
        self.baseline = baseline
        self.baseline_hits = set()  # indices of entries that suppressed ≥1 finding
        self.suppressed = 0

    def add(self, rule, sf, line_idx, msg):
        """line_idx is 0-based; reported 1-based. Applies inline allow
        and baseline suppression."""
        family = FAMILY_OF[rule[0]]
        if sf is not None and sf.allow_on(line_idx, family):
            if family != "panic":  # panic allows additionally need a reason
                self.suppressed += 1
                return
            if sf.allow_reason(line_idx, "panic"):
                self.suppressed += 1
                return
        rel = sf.rel if sf is not None else "<repo>"
        for idx, (brule, bpath, bmsg, _lineno, _raw) in enumerate(self.baseline):
            if rule == brule and bpath in rel and bmsg in msg:
                self.baseline_hits.add(idx)
                self.suppressed += 1
                return
        self.rows.append((rule, rel, line_idx + 1, msg))


# --- rule S: schema / digest drift ----------------------------------------

SCHEMA_CONST = re.compile(r"const\s+([A-Z][A-Z0-9_]*)_SCHEMA\s*:\s*u32\s*=\s*(\d+)")
FIELD_KEY = re.compile(r'\(\s*"([a-z][a-z0-9_]*)"\s*,')


def extract_schemas(files):
    """{name: (version, sorted field tuple, SourceFile)} from every file
    declaring a `*_SCHEMA: u32` const."""
    out = {}
    for sf in files:
        text = sf.code_text(strings=True)
        m = SCHEMA_CONST.search(text)
        if not m:
            continue
        name = m.group(1).lower()
        version = int(m.group(2))
        fields = tuple(sorted(set(FIELD_KEY.findall(text))))
        out[name] = (version, fields, sf)
    return out


def parse_lock(path):
    locks = {}
    with open(path, encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            m = re.match(r"(\w+)\s+v(\d+)\s+fields=(\S*)", line)
            if not m:
                fail(f"{path}: unparseable lock line: {line}")
            locks[m.group(1)] = (int(m.group(2)), tuple(m.group(3).split(",")) if m.group(3) else ())
    return locks


def write_lock(path, schemas):
    lines = [
        "# xrlint schemas.lock — per-schema serialized-field fingerprints.",
        "# Regenerate ONLY after bumping the matching *_SCHEMA const:",
        "#   python3 tools/xrlint/xrlint.py rust/src --update-schemas-lock",
        "# (see DESIGN.md §3.7 for the schema-bump workflow)",
    ]
    for name in sorted(schemas):
        version, fields, _ = schemas[name]
        lines.append(f"{name} v{version} fields={','.join(fields)}")
    with open(path, "w", encoding="utf-8") as fh:
        fh.write("\n".join(lines) + "\n")


def rule_schema(files, lock_path, update, findings):
    schemas = extract_schemas(files)
    if update:
        write_lock(lock_path, schemas)
        for name in sorted(schemas):
            version, fields, _ = schemas[name]
            print(f"schemas.lock: recorded {name} v{version} ({len(fields)} fields)")
        return True
    if not os.path.exists(lock_path):
        if schemas:
            findings.add(
                "S002",
                None,
                0,
                f"schemas.lock not found at {lock_path}; run --update-schemas-lock "
                f"to record the current schema shapes",
            )
        return False
    locked = parse_lock(lock_path)
    for name, (version, fields, sf) in sorted(schemas.items()):
        line = _const_line(sf, name)
        if name not in locked:
            findings.add(
                "S002", sf, line,
                f"schema `{name}` (v{version}) is not in schemas.lock; a new schema "
                f"must be recorded with --update-schemas-lock",
            )
            continue
        lver, lfields = locked[name]
        if version == lver and fields != lfields:
            added = sorted(set(fields) - set(lfields))
            removed = sorted(set(lfields) - set(fields))
            delta = "; ".join(
                p for p in (
                    f"added: {', '.join(added)}" if added else "",
                    f"removed: {', '.join(removed)}" if removed else "",
                ) if p
            )
            findings.add(
                "S001", sf, line,
                f"schema `{name}` serialized field set changed without a version bump "
                f"(still v{version}; {delta}) — bump {name.upper()}_SCHEMA and "
                f"re-run --update-schemas-lock",
            )
        elif version != lver:
            findings.add(
                "S002", sf, line,
                f"schema `{name}` version changed (lock v{lver} -> code v{version}); "
                f"re-record with --update-schemas-lock so the lint tracks the new shape",
            )
    for name in sorted(set(locked) - set(schemas)):
        findings.add(
            "S002", None, 0,
            f"schemas.lock records schema `{name}` but no scanned file declares "
            f"{name.upper()}_SCHEMA — deleted schemas must be removed from the lock",
        )
    # S003: nothing may be appended to a body after splice_digest sealed it.
    post_seal = re.compile(r"Json::obj\s*\(|push_str\s*\(|format!\s*\(|write!\s*\(")
    for _, (_, _, sf) in sorted(schemas.items()):
        lines = sf.code_ns[: sf.test_start]
        for fname, start, end in function_spans(sf):
            seal = None
            for i in range(start - 1, min(end, len(lines))):
                if re.search(r"(?<![\w:])splice_digest\s*\(", lines[i]) and not re.search(
                    r"fn\s+splice_digest", lines[i]
                ):
                    seal = i
            if seal is None:
                continue
            # The splice call's own argument may span lines; skip until
            # its parenthesis closes before hunting for post-seal renders.
            depth = 0
            j = seal
            closed = False
            while j < min(end, len(lines)) and not closed:
                for ch in lines[j]:
                    if ch == "(":
                        depth += 1
                    elif ch == ")":
                        depth -= 1
                        if depth == 0:
                            closed = True
                j += 1
            for i in range(j, min(end, len(lines))):
                if post_seal.search(lines[i]):
                    findings.add(
                        "S003", sf, i,
                        f"`{fname}` renders content after splice_digest sealed the "
                        f"body — the appended bytes escape digest coverage",
                    )
    return False


def _const_line(sf, name):
    pat = re.compile(rf"const\s+{name.upper()}_SCHEMA")
    for i, line in enumerate(sf.code_ws):
        if pat.search(line):
            return i
    return 0


# --- rules F/R: float determinism inside bit-identical regions -------------

def rule_float(files, findings):
    for sf in files:
        regions = []  # (start, end) 0-based, inclusive
        stack = []
        for i in sorted(sf.directives):
            kind, args = sf.directives[i]
            if kind == "region" and args == "bit-identical":
                stack.append(i)
            elif kind == "endregion" and args == "bit-identical":
                if not stack:
                    findings.add("R001", sf, i, "endregion(bit-identical) without a matching region")
                else:
                    regions.append((stack.pop(), i))
        for i in stack:
            findings.add("R001", sf, i, "region(bit-identical) never closed (missing endregion)")
        want = REQUIRED_REGIONS.get(sf.rel)
        if want and len(regions) < want:
            findings.add(
                "R002", sf, 0,
                f"{sf.rel} must fence its kernels with at least {want} "
                f"region(bit-identical) guard(s); found {len(regions)} — the f32 fold "
                f"order here is the repo's bit-identity contract",
            )
        for start, end in regions:
            for i in range(start + 1, min(end, len(sf.code_ns))):
                line = sf.code_ns[i]
                if re.search(r"\.sum\s*(?:::<[^>]*>)?\(\)|\.fold\s*\(", line):
                    window = " ".join(sf.code_ns[max(0, i - 2) : i + 1])
                    if not ORDERED_ITER.search(window):
                        findings.add(
                            "F001", sf, i,
                            "unordered fold: .sum()/.fold( without a slice-backed "
                            "iterator in reach — accumulation order must be fixed "
                            "inside a bit-identical region",
                        )
                if re.search(r"\bHashMap\b|\bHashSet\b|\.values\(\)|\.keys\(\)", line):
                    findings.add(
                        "F002", sf, i,
                        "unordered container inside a bit-identical region — HashMap/"
                        "HashSet iteration order is nondeterministic",
                    )
                if ".mul_add(" in line:
                    findings.add(
                        "F003", sf, i,
                        "mul_add contracts rounding — bit-identical regions must keep "
                        "the separate mul/add the oracle paths use",
                    )
                if re.search(r"\bspawn\s*\(|thread::scope|par_iter", line):
                    findings.add(
                        "F004", sf, i,
                        "thread spawn inside a bit-identical region — merge order must "
                        "not depend on scheduling",
                    )


# --- rule L: lock-order graph ---------------------------------------------

ACQUIRE = re.compile(r"(?:let\s+(?:mut\s+)?(\w+)\s*=\s*(?:match\s+)?)?([\w.()?*&]*?)\.lock(?:_shared)?\s*\(\)")

# Call sites that feed the interprocedural summaries: free calls, path
# calls (`Type::f(`) and `self.f(` — but NOT receiver-dispatched method
# names (`map.get(`), which are unresolvable by name and collide across
# files (`get`, `insert`, `clone` …), manufacturing false lock edges.
CALL = re.compile(r"(?:(?<=self\.)|(?<![\w!.]))(\w+)\s*\(")


def lock_name(rel, ident):
    for frag, field, name in LOCK_ALIASES:
        if frag in rel and ident == field:
            return name
    stem = os.path.splitext(os.path.basename(rel))[0]
    return f"{stem}.{ident}"


def receiver_ident(sf, line_idx, recv):
    """Last named component of the receiver chain; looks up for
    continuation lines (`.lock()` starting its own line)."""
    chain = recv
    k = line_idx
    while (not chain or chain.lstrip().startswith(".")) and k > 0:
        k -= 1
        chain = sf.code_ns[k].strip() + chain
    parts = [p for p in re.split(r"[.\s()&*?]+", chain) if p and p not in ("self", "co", "mut", "let")]
    parts = [p for p in parts if not p.isdigit()]
    return parts[-1] if parts else "anon"


def rule_lock(files, findings):
    # Pass 1: per-function direct acquisitions + guard scopes + edges.
    # Summaries are keyed (file, fn name): bare-name keying merged every
    # `new` in the repo into one summary, which under transitive closure
    # manufactured lock edges (and cycles) out of `Vec::new(` calls.
    fn_locks = {}  # (file, fn name) -> set of lock names acquired directly
    defs = {}  # fn name -> set of files defining it
    per_fn = []  # (sf, fname, start, end)
    for sf in files:
        for fname, start, end in function_spans(sf):
            per_fn.append((sf, fname, start, end))
            defs.setdefault(fname, set()).add(sf.rel)
            acquired = set()
            for i in range(start - 1, min(end, sf.test_start, len(sf.code_ns))):
                for m in ACQUIRE.finditer(sf.code_ns[i]):
                    acquired.add(lock_name(sf.rel, receiver_ident(sf, i, m.group(2))))
                if re.search(r"\.lock_dir\s*\(", sf.code_ns[i]):
                    acquired.add("cache.flock")
            if acquired:
                fn_locks.setdefault((sf.rel, fname), set()).update(acquired)

    def resolve(rel, callee):
        """Callee name -> summary key: same-file definition first, else a
        globally unique one; ambiguous cross-file names resolve to None
        rather than to the union of every same-named function."""
        homes = defs.get(callee)
        if not homes:
            return None
        if rel in homes:
            return (rel, callee)
        if len(homes) == 1:
            return (next(iter(homes)), callee)
        return None

    # Pass 1b: interprocedural fixpoint. Propagate each function's
    # acquisition set up the call graph until nothing changes, so a lock
    # taken N calls deep still contributes edges at every transitive
    # caller — one-level summaries missed any chain longer than
    # caller -> callee -> lock.
    fn_calls = {}  # (file, fn name) -> set of resolved callee keys
    for sf, fname, start, end in per_fn:
        callees = fn_calls.setdefault((sf.rel, fname), set())
        for i in range(start - 1, min(end, sf.test_start, len(sf.code_ns))):
            for cm in CALL.finditer(sf.code_ns[i]):
                key = resolve(sf.rel, cm.group(1))
                if key is not None and key != (sf.rel, fname):
                    callees.add(key)
    changed = True
    while changed:
        changed = False
        for caller, callees in fn_calls.items():
            inherited = set()
            for key in callees:
                inherited |= fn_locks.get(key, set())
            have = fn_locks.get(caller, set())
            if not inherited <= have:
                fn_locks[caller] = have | inherited
                changed = True

    edges = {}  # (from, to) -> (rel, line)
    io_sites = []
    for sf, fname, start, end in per_fn:
        held = []  # (lock, var name or None, brace depth at acquisition)
        depth = 0
        limit = min(end, sf.test_start, len(sf.code_ns))
        for i in range(start - 1, limit):
            line = sf.code_ns[i]
            for m in ACQUIRE.finditer(line):
                var, recv = m.group(1), m.group(2)
                lock = lock_name(sf.rel, receiver_ident(sf, i, recv))
                for h, _, _ in held:
                    if h != lock:
                        edges.setdefault((h, lock), (sf.rel, i + 1))
                if var and var != "_":
                    held.append((lock, var, depth))
            m = re.search(r"(?:let\s+(?:mut\s+)?(\w+)\s*=\s*)?(?:self\.)?lock_dir\s*\(", line)
            if m and "fn " not in line:
                for h, _, _ in held:
                    if h != "cache.flock":
                        edges.setdefault((h, "cache.flock"), (sf.rel, i + 1))
                if m.group(1) and m.group(1) != "_":
                    held.append(("cache.flock", m.group(1), depth))
            # Interprocedural: calling a fn whose fixpoint-closed summary
            # acquires locks, while holding a lock, creates the same edges
            # as acquiring those locks here directly.
            if held:
                for cm in CALL.finditer(line):
                    key = resolve(sf.rel, cm.group(1))
                    if key is None or key == (sf.rel, fname) or key not in fn_locks:
                        continue
                    for h, _, _ in held:
                        for inner in fn_locks[key]:
                            if inner != h:
                                edges.setdefault((h, inner), (sf.rel, i + 1))
                for h, _, _ in held:
                    if h in NO_IO_LOCKS and IO_TOKENS.search(line):
                        io_sites.append((sf, i, h))
            # Scope maintenance: explicit drops, then brace depth.
            dm = re.findall(r"\bdrop\s*\(\s*(\w+)\s*\)", line)
            if dm:
                held = [h for h in held if h[1] not in dm]
            depth += line.count("{")
            closes = line.count("}")
            if closes:
                depth -= closes
                held = [h for h in held if h[2] < depth or (h[2] == depth and "{" not in line)]
                held = [h for h in held if h[2] <= depth]

    # Cycle detection (DFS) over the acquired-while-held graph.
    graph = {}
    for (a, b), site in edges.items():
        graph.setdefault(a, []).append(b)
    state = {}
    cycle = []

    def dfs(node, path):
        state[node] = 1
        for nxt in graph.get(node, ()):
            if state.get(nxt) == 1:
                cycle.append(path[path.index(nxt):] + [nxt] if nxt in path else [node, nxt])
                return True
            if state.get(nxt, 0) == 0 and dfs(nxt, path + [nxt]):
                return True
        state[node] = 2
        return False

    for node in sorted(graph):
        if state.get(node, 0) == 0 and dfs(node, [node]):
            break
    if cycle:
        loop = cycle[0]
        key = None
        for a, b in zip(loop, loop[1:]):
            if (a, b) in edges:
                key = (a, b)
                break
        rel, line = edges[key] if key else ("<graph>", 0)
        sf = next((s for s in files if s.rel == rel), None)
        findings.add(
            "L001", sf, line - 1,
            f"lock-order cycle: {' -> '.join(loop)} — a cycle in the "
            f"acquired-while-held graph is a deadlock waiting for schedule",
        )
    for sf, i, h in io_sites:
        findings.add(
            "L002", sf, i,
            f"filesystem I/O while holding `{h}` — this lock sits on every status/"
            f"submit poll path; move the I/O outside the critical section",
        )


# --- rule P: panic-path audit ----------------------------------------------

PANIC_TOKENS = re.compile(
    r"\.unwrap\(\)|\.expect\s*\(|\bpanic!\s*\(|\bunreachable!\s*\(|"
    r"\btodo!\s*\(|\bunimplemented!\s*\("
)
INDEXING = re.compile(r"[\w)\]]\[")


def rule_panic(files, findings):
    for sf in files:
        if not (sf.rel.startswith("service/") or sf.rel == "runtime/pool.rs"):
            continue
        for i in range(min(sf.test_start, len(sf.code_ns))):
            line = sf.code_ns[i]
            hit = None
            if PANIC_TOKENS.search(line):
                hit = PANIC_TOKENS.search(line).group(0).strip("(").strip()
            elif INDEXING.search(line):
                hit = "indexing"
            if hit is None:
                continue
            reason = sf.allow_reason(i, "panic")
            if reason:
                continue
            if reason == "":
                findings.add(
                    "P001", sf, i,
                    f"`{hit}` on a service/pool request path has an allow(panic) with "
                    f'no reason — write allow(panic, "<why this cannot fire>")',
                )
                continue
            findings.add(
                "P001", sf, i,
                f"`{hit}` on a service/pool request path without "
                f'`// xrlint: allow(panic, "<why>")` — a worker panic kills the '
                f"executor; return an error (HTTP 400/500) instead or justify it",
            )


# --- rule C: surface consistency -------------------------------------------

def rule_surface(files, src_root, findings):
    by_rel = {sf.rel: sf for sf in files}
    args_sf = by_rel.get("cli/args.rs")
    main_sf = by_rel.get("main.rs")
    if args_sf and main_sf:
        text = args_sf.code_text(strings=True)
        registered = set()
        for const in ("VALUED", "FLAGS"):
            m = re.search(rf"const\s+{const}\s*:[^=]*=\s*&\[(.*?)\]", text, re.S)
            if m:
                registered.update(re.findall(r'"([a-z][a-z0-9-]*)"', m.group(1)))
        usage = re.search(r"const\s+USAGE[^=]*=\s*(r?#*\"|\")", main_sf.raw)
        usage_opts = set()
        if usage:
            _, literal = _scan_string(main_sf.raw, usage.start(1))
            usage_opts = set(re.findall(r"--([a-z][a-z0-9-]*)", literal))
        for opt in sorted(registered - usage_opts):
            findings.add(
                "C001", args_sf, _line_of(args_sf, f'"{opt}"'),
                f"CLI option --{opt} is registered in cli/args.rs but absent from "
                f"the USAGE text in main.rs",
            )
        for opt in sorted(usage_opts - registered):
            findings.add(
                "C001", main_sf, _line_of(main_sf, f"--{opt}"),
                f"USAGE documents --{opt} but cli/args.rs does not register it "
                f"(users get UnknownOption)",
            )
    http_sf = by_rel.get("service/http.rs")
    design = _find_up(src_root, "DESIGN.md")
    if http_sf and design:
        code_routes = set()
        text = http_sf.code_text(strings=True)
        m = re.search(r"fn\s+handle_request.*?\n\}", text, re.S)
        body = m.group(0) if m else text
        for mm in re.finditer(r'\(\s*"(GET|POST|PUT|DELETE)"\s*,\s*"(/[^"]*)"', body):
            code_routes.add((mm.group(1), mm.group(2)))
        for mm in re.finditer(
            r'\(\s*"(GET|POST|PUT|DELETE)"\s*,\s*\w+\s*\)\s*if\s*\w+\.starts_with\(\s*"(/[^"]*)"',
            body,
        ):
            code_routes.add((mm.group(1), mm.group(2)))
        doc_routes = set()
        with open(design, encoding="utf-8") as fh:
            dtext = fh.read()
        sec = re.search(r"#+ *§3\.6.*?(?=\n#+ *§|\Z)", dtext, re.S)
        if sec:
            for mm in re.finditer(r"`(GET|POST|PUT|DELETE)\s+(/\S+?)`", sec.group(0)):
                path = mm.group(2)
                if "{" in path:
                    path = path[: path.index("{")]
                doc_routes.add((mm.group(1), path))
        norm = lambda routes: {(m2, p[: p.index("{")] if "{" in p else p) for m2, p in routes}
        code_n, doc_n = norm(code_routes), norm(doc_routes)
        for method, path in sorted(code_n - doc_n):
            findings.add(
                "C002", http_sf, _line_of(http_sf, f'"{path}'),
                f"route {method} {path} is served by service/http.rs but missing from "
                f"the DESIGN.md §3.6 endpoint table",
            )
        for method, path in sorted(doc_n - code_n):
            findings.add(
                "C002", http_sf, 0,
                f"DESIGN.md §3.6 documents {method} {path} but service/http.rs does "
                f"not route it",
            )


def _line_of(sf, needle):
    for i, line in enumerate(sf.raw_lines):
        if needle in line:
            return i
    return 0


def _find_up(start, name, levels=4):
    d = os.path.abspath(start)
    for _ in range(levels):
        d = os.path.dirname(d)
        cand = os.path.join(d, name)
        if os.path.exists(cand):
            return cand
    return None


# --- driver ----------------------------------------------------------------

def load_baseline(path):
    """Entries as (rule, path-sub, msg-sub, lineno, raw-line) so stale
    entries can be reported at their own file:line and pruned by text."""
    rows = []
    if path and os.path.exists(path):
        with open(path, encoding="utf-8") as fh:
            for lineno, line in enumerate(fh, 1):
                line = line.strip()
                if not line or line.startswith("#"):
                    continue
                parts = line.split("|", 2)
                if len(parts) != 3:
                    fail(f"{path}: baseline line needs RULE|path-sub|msg-sub: {line}")
                rows.append((parts[0], parts[1], parts[2], lineno, line))
    return rows


def prune_baseline(path, baseline, hits):
    """Rewrite the baseline keeping comments, blanks, and entries that
    suppressed at least one finding this run."""
    live = {raw for idx, (_r, _p, _m, _ln, raw) in enumerate(baseline) if idx in hits}
    kept = []
    with open(path, encoding="utf-8") as fh:
        for line in fh:
            s = line.strip()
            if not s or s.startswith("#") or s in live:
                kept.append(line)
    with open(path, "w", encoding="utf-8") as fh:
        fh.writelines(kept)


def main():
    argv = sys.argv[1:]
    update = "--update-schemas-lock" in argv
    prune = "--prune-baseline" in argv
    argv = [a for a in argv if a not in ("--update-schemas-lock", "--prune-baseline")]
    lock_path = None
    baseline_path = None
    pos = []
    i = 0
    while i < len(argv):
        if argv[i] == "--schemas-lock":
            i += 1
            lock_path = argv[i] if i < len(argv) else fail("--schemas-lock needs a path")
        elif argv[i] == "--baseline":
            i += 1
            baseline_path = argv[i] if i < len(argv) else fail("--baseline needs a path")
        elif argv[i].startswith("--"):
            fail(f"unknown option {argv[i]}")
        else:
            pos.append(argv[i])
        i += 1
    if len(pos) != 1:
        fail("usage: xrlint.py SRC_ROOT [--schemas-lock PATH] [--baseline PATH] "
             "[--update-schemas-lock] [--prune-baseline]")
    src_root = pos[0]
    if not os.path.isdir(src_root):
        fail(f"{src_root}: not a directory")
    here = os.path.dirname(os.path.abspath(__file__))
    if lock_path is None:
        lock_path = os.path.join(here, "schemas.lock")
    if baseline_path is None:
        cand = os.path.join(here, "baseline.txt")
        baseline_path = cand if os.path.exists(cand) else None

    files = []
    for dirpath, dirnames, filenames in os.walk(src_root):
        dirnames.sort()
        for fn in sorted(filenames):
            if fn.endswith(".rs"):
                rel = os.path.relpath(os.path.join(dirpath, fn), src_root).replace(os.sep, "/")
                files.append(SourceFile(src_root, rel))
    if not files:
        fail(f"{src_root}: no .rs files found")

    findings = Findings(load_baseline(baseline_path))
    updated = rule_schema(files, lock_path, update, findings)
    if updated:
        print("xrlint: schemas.lock updated")
        return 0
    rule_float(files, findings)
    rule_lock(files, findings)
    rule_panic(files, findings)
    rule_surface(files, src_root, findings)

    # Stale-baseline audit: an entry that suppressed nothing over the
    # whole run guards debt that no longer exists — flag it (B001) so the
    # suppression hole closes, or drop it in place with --prune-baseline.
    stale = [
        (idx, entry) for idx, entry in enumerate(findings.baseline)
        if idx not in findings.baseline_hits
    ]
    if prune:
        if baseline_path is None or not os.path.exists(baseline_path):
            fail("--prune-baseline: no baseline file to prune")
        prune_baseline(baseline_path, findings.baseline, findings.baseline_hits)
        print(
            f"xrlint: baseline pruned — {len(stale)} stale entr"
            f"{'y' if len(stale) == 1 else 'ies'} removed, "
            f"{len(findings.baseline) - len(stale)} kept"
        )
    else:
        for _idx, (brule, _bpath, _bmsg, lineno, raw) in stale:
            findings.rows.append((
                "B001", baseline_path, lineno,
                f"stale baseline entry `{raw}` suppressed no {brule} finding this "
                f"run — the debt it excused is gone; delete the line or run "
                f"--prune-baseline",
            ))

    for rule, rel, line, msg in sorted(findings.rows):
        print(f"{rule} {rel}:{line} {msg}", file=sys.stderr)
    if findings.rows:
        print(
            f"xrlint: {len(findings.rows)} finding(s) "
            f"({findings.suppressed} suppressed) over {len(files)} file(s)",
            file=sys.stderr,
        )
        return 1
    print(f"xrlint: OK ({len(files)} files, {findings.suppressed} suppressed finding(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())
