#!/usr/bin/env python3
"""Self-test for tools/xrlint/xrlint.py (stdlib-only; run before the
real lint in CI, like tools/test_check_bench_gate.py).

Three layers:
  1. Fixture corpus: every `selftest/<family>_bad` tree must fail with
     that family's rule codes; every `<family>_good` tree must pass.
  2. The real repo must lint clean: `xrlint.py rust/src` exits 0.
  3. Mutation checks on a copy of rust/src — removing a digest-rendered
     field, deleting a region(bit-identical) fence, or stripping an
     allow(panic) annotation must each flip the lint to failing, and a
     legitimate schema bump must be recordable via --update-schemas-lock.
"""

import os
import shutil
import subprocess
import sys
import tempfile

HERE = os.path.dirname(os.path.abspath(__file__))
XRLINT = os.path.join(HERE, "xrlint.py")
REPO = os.path.dirname(os.path.dirname(HERE))
SELFTEST = os.path.join(HERE, "selftest")

failures = []


def run(*args):
    return subprocess.run(
        [sys.executable, XRLINT, *args], capture_output=True, text=True
    )


def check(name, ok, detail=""):
    status = "ok" if ok else "FAIL"
    print(f"[{status}] {name}")
    if not ok:
        failures.append(name)
        if detail:
            print(detail)


def expect_fail(case, codes, lock):
    src = os.path.join(SELFTEST, case, "src")
    r = run(src, "--schemas-lock", lock)
    out = r.stdout + r.stderr
    ok = r.returncode == 1 and all(c in out for c in codes)
    check(f"{case} fails with {'/'.join(codes)}", ok, out)


def expect_pass(case, lock):
    src = os.path.join(SELFTEST, case, "src")
    r = run(src, "--schemas-lock", lock)
    ok = r.returncode == 0
    check(f"{case} passes", ok, r.stdout + r.stderr)


def case_lock(case):
    own = os.path.join(SELFTEST, case, "schemas.lock")
    return own if os.path.exists(own) else os.path.join(SELFTEST, "empty.lock")


def mutate(tmp, rel, pred, why):
    """Drop the first line of rel matching pred from the copied tree."""
    path = os.path.join(tmp, "src", rel)
    with open(path, encoding="utf-8") as fh:
        lines = fh.readlines()
    kept, dropped = [], 0
    for line in lines:
        if not dropped and pred(line):
            dropped = 1
            continue
        kept.append(line)
    if not dropped:
        raise AssertionError(f"mutation target not found in {rel}: {why}")
    with open(path, "w", encoding="utf-8") as fh:
        fh.writelines(kept)


def fresh_copy(tmp_root, label):
    tmp = os.path.join(tmp_root, label)
    shutil.copytree(os.path.join(REPO, "rust", "src"), os.path.join(tmp, "src"))
    return tmp


def main():
    # 1. Fixture corpus — one bad + one good tree per rule family.
    expect_fail("schema_bad", ["S001", "S003"], case_lock("schema_bad"))
    expect_pass("schema_good", case_lock("schema_good"))
    expect_fail("float_bad", ["F001", "F002", "F003", "F004", "R001", "R002"],
                case_lock("float_bad"))
    expect_pass("float_good", case_lock("float_good"))
    expect_fail("lock_bad", ["L001", "L002"], case_lock("lock_bad"))
    expect_pass("lock_good", case_lock("lock_good"))
    # Interprocedural fixpoint: the cycle sits three calls deep, where
    # one-level callee summaries were blind.
    expect_fail("lock_deep_bad", ["L001"], case_lock("lock_deep_bad"))
    expect_pass("lock_deep_good", case_lock("lock_deep_good"))
    expect_fail("panic_bad", ["P001"], case_lock("panic_bad"))
    expect_pass("panic_good", case_lock("panic_good"))
    expect_fail("surface_bad", ["C001", "C002"], case_lock("surface_bad"))
    expect_pass("surface_good", case_lock("surface_good"))

    # Suppression mechanism: a baseline entry silences panic_bad's P001.
    with tempfile.TemporaryDirectory() as tmp:
        baseline = os.path.join(tmp, "baseline.txt")
        with open(baseline, "w", encoding="utf-8") as fh:
            fh.write("P001|service/h.rs|unwrap\n")
        r = run(os.path.join(SELFTEST, "panic_bad", "src"),
                "--schemas-lock", case_lock("panic_bad"), "--baseline", baseline)
        check("baseline suppresses panic_bad", r.returncode == 0,
              r.stdout + r.stderr)

        # Stale-baseline detection: an entry that suppresses nothing is
        # itself a B001 finding...
        with open(baseline, "w", encoding="utf-8") as fh:
            fh.write("# legacy debt\n")
            fh.write("P001|service/h.rs|unwrap\n")
            fh.write("L001|nowhere.rs|no such cycle\n")
        r = run(os.path.join(SELFTEST, "panic_bad", "src"),
                "--schemas-lock", case_lock("panic_bad"), "--baseline", baseline)
        out = r.stdout + r.stderr
        check("stale baseline entry fails with B001",
              r.returncode == 1 and "B001" in out and "no such cycle" in out, out)
        # ...and --prune-baseline rewrites the file keeping only the
        # entries (and comments) that earned their keep.
        r = run(os.path.join(SELFTEST, "panic_bad", "src"),
                "--schemas-lock", case_lock("panic_bad"), "--baseline", baseline,
                "--prune-baseline")
        with open(baseline, encoding="utf-8") as fh:
            pruned = fh.read()
        check("--prune-baseline drops the stale entry",
              r.returncode == 0 and "no such cycle" not in pruned
              and "P001|service/h.rs|unwrap" in pruned and "# legacy debt" in pruned,
              r.stdout + r.stderr + "\n--- baseline after prune ---\n" + pruned)
        r = run(os.path.join(SELFTEST, "panic_bad", "src"),
                "--schemas-lock", case_lock("panic_bad"), "--baseline", baseline)
        check("clean after prune", r.returncode == 0, r.stdout + r.stderr)

    # 2. The real repo lints clean with the checked-in schemas.lock.
    r = run(os.path.join(REPO, "rust", "src"))
    check("repo rust/src lints clean", r.returncode == 0, r.stdout + r.stderr)

    # 3. Mutation checks: each guarded invariant, when broken, fails.
    with tempfile.TemporaryDirectory() as tmp_root:
        tmp = fresh_copy(tmp_root, "field")
        mutate(tmp, os.path.join("dse", "sweep.rs"),
               lambda l: '("chunks_done"' in l, "chunks_done render")
        r = run(os.path.join(tmp, "src"))
        check("removing a digest-rendered field fails (S001)",
              r.returncode == 1 and "S001" in r.stderr, r.stdout + r.stderr)

        tmp = fresh_copy(tmp_root, "region")
        mutate(tmp, os.path.join("runtime", "host.rs"),
               lambda l: "xrlint: region(bit-identical)" in l, "region fence")
        r = run(os.path.join(tmp, "src"))
        check("deleting a region(bit-identical) fence fails (R001/R002)",
              r.returncode == 1 and ("R001" in r.stderr or "R002" in r.stderr),
              r.stdout + r.stderr)

        tmp = fresh_copy(tmp_root, "allow")
        mutate(tmp, os.path.join("runtime", "pool.rs"),
               lambda l: "xrlint: allow(panic" in l, "allow(panic) annotation")
        r = run(os.path.join(tmp, "src"))
        check("stripping an allow(panic) fails (P001)",
              r.returncode == 1 and "P001" in r.stderr, r.stdout + r.stderr)

        # Legitimate schema bump workflow: field change + version bump is
        # still S002 (stale lock) until --update-schemas-lock re-records,
        # after which the lint is clean again.
        tmp = fresh_copy(tmp_root, "bump")
        sweep = os.path.join(tmp, "src", "dse", "sweep.rs")
        with open(sweep, encoding="utf-8") as fh:
            text = fh.read()
        assert "SWEEP_CHECKPOINT_SCHEMA: u32 = 2" in text
        text = text.replace("SWEEP_CHECKPOINT_SCHEMA: u32 = 2",
                            "SWEEP_CHECKPOINT_SCHEMA: u32 = 3")
        with open(sweep, "w", encoding="utf-8") as fh:
            fh.write(text)
        lock = os.path.join(tmp, "schemas.lock")
        shutil.copy(os.path.join(HERE, "schemas.lock"), lock)
        r = run(os.path.join(tmp, "src"), "--schemas-lock", lock)
        check("version bump without re-record fails (S002)",
              r.returncode == 1 and "S002" in r.stderr, r.stdout + r.stderr)
        r = run(os.path.join(tmp, "src"), "--schemas-lock", lock,
                "--update-schemas-lock")
        check("--update-schemas-lock re-records", r.returncode == 0,
              r.stdout + r.stderr)
        r = run(os.path.join(tmp, "src"), "--schemas-lock", lock)
        check("clean after re-record", r.returncode == 0, r.stdout + r.stderr)

    if failures:
        print(f"\n{len(failures)} xrlint self-test failure(s)", file=sys.stderr)
        return 1
    print("\nall xrlint self-tests passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
