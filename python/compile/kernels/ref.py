"""Pure-jnp oracle for the batched tCDP metric evaluation (paper §3.3).

This is the ground-truth implementation of the matrix formalization the
Pallas kernel (``tcdp_kernel.py``) must match bit-for-bit (up to f32
accumulation order):

* task energy   ``E_T = N × (P_leak/f_clk + P_dyn/f_clk)``   (§3.3.1)
* task delay    ``D_T = N × D_k``                             (§3.3.2)
* operational   ``C_op = CI_use · ||E||₁``                    (§3.3.3)
* embodied      ``C_emb = (C_comp · online) · ||D||₁ / LT_op``(§3.3.3)
* tCDP          ``(C_op + β·C_emb) · ||D||₁``                 (§3.1/3.2)

plus the classic metric suite (EDP/CDP/CEP/CE²P/C²EP) and the §3.2
feasibility mask (per-task QoS bounds and an average-power cap).

Everything is batched over the leading config dimension ``C`` — one row
per candidate hardware configuration.
"""

import jax.numpy as jnp

#: Number of metric rows in the output.
NUM_METRICS = 12

#: Output row order of the metrics matrix.
METRIC_ROWS = (
    "energy",    # 0  ||E||1 per config, J
    "delay",     # 1  ||D||1 per config, s
    "c_op",      # 2  operational carbon, g
    "c_emb",     # 3  amortized embodied carbon, g
    "c_total",   # 4  c_op + c_emb, g
    "tcdp",      # 5  (c_op + beta*c_emb) * delay, g*s
    "edp",       # 6  energy * delay
    "cdp",       # 7  c_emb * delay
    "cep",       # 8  c_emb * energy
    "ce2p",      # 9  c_emb * energy^2
    "c2ep",      # 10 c_emb^2 * energy
    "feasible",  # 11 1.0 if QoS and power constraints hold
)


def dse_metrics_ref(n, p_leak, p_dyn, f_clk, d_k, c_comp, online, qos, scalars):
    """Reference evaluation.

    Args:
      n:       f32[T, K]  kernel calls per task.
      p_leak:  f32[C, K]  leakage power term per config/kernel (paper's
               P_leak; scaled so that P/f_clk is energy per call, J).
      p_dyn:   f32[C, K]  dynamic power term per config/kernel.
      f_clk:   f32[C, 1]  clock per config, Hz (pad rows with 1.0).
      d_k:     f32[C, K]  per-kernel delay per config, s.
      c_comp:  f32[C, J]  per-component embodied carbon, g.
      online:  f32[J]     provisioning mask (§3.3.3 binary vector).
      qos:     f32[T]     per-task delay bounds, s (+inf = unconstrained).
      scalars: f32[4]     [CI_use (g/J), operational lifetime (s), beta,
                           p_max (W)].

    Returns:
      (metrics f32[12, C], d_task f32[C, T])
    """
    ci_use, lifetime, beta, p_max = scalars[0], scalars[1], scalars[2], scalars[3]

    # §3.3.1 task energy: per-call energy e = (P_leak + P_dyn) / f_clk.
    e_k = (p_leak + p_dyn) / f_clk                      # [C, K]
    e_task = e_k @ n.T                                  # [C, T]
    # §3.3.2 task delay.
    d_task = d_k @ n.T                                  # [C, T]

    energy = jnp.sum(e_task, axis=1)                    # [C]
    delay = jnp.sum(d_task, axis=1)                     # [C]

    # §3.3.3 operational and amortized embodied carbon.
    c_op = ci_use * energy
    c_emb_overall = c_comp @ online                     # [C]
    c_emb = c_emb_overall * delay / lifetime

    c_total = c_op + c_emb
    tcdp = (c_op + beta * c_emb) * delay

    edp = energy * delay
    cdp = c_emb * delay
    cep = c_emb * energy
    ce2p = cep * energy
    c2ep = c_emb * cep

    # §3.2 constraints: per-task QoS delay bounds and average power cap.
    qos_ok = jnp.all(d_task <= qos[None, :], axis=1)
    avg_power = energy / jnp.maximum(delay, 1e-30)
    power_ok = avg_power <= p_max
    feasible = jnp.where(qos_ok & power_ok, 1.0, 0.0).astype(jnp.float32)

    metrics = jnp.stack(
        [energy, delay, c_op, c_emb, c_total, tcdp, edp, cdp, cep, ce2p, c2ep, feasible],
        axis=0,
    )
    return metrics, d_task
