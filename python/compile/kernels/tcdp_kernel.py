"""Layer-1 Pallas kernel: blocked batched tCDP metric evaluation.

The DSE hot loop evaluates the §3.3 matrix formalization for a *batch* of
candidate hardware configurations. This kernel tiles the config dimension
``C`` into VMEM-resident blocks of ``block_c`` rows; each grid step

1. loads one ``[Cb, K]`` slab of per-config kernel power/delay data,
2. runs the two MXU-shaped contractions ``[Cb, K] @ [K, T]`` (task energy
   and task delay),
3. fuses the whole carbon + metric suite elementwise in VMEM, and
4. writes one ``[12, Cb]`` metrics slab and one ``[Cb, T]`` task-delay
   slab — a single HBM round trip per slab.

Scalars (CI_use, lifetime, β, p_max) ride in a broadcast ``(1, 4)`` block.

TPU notes (DESIGN.md §Hardware-Adaptation): ``K`` and ``T`` are padded to
lane-friendly sizes at AOT time (32 and 8); the contraction uses
``preferred_element_type=f32``. Lowered with ``interpret=True`` because
the CPU PJRT client cannot execute Mosaic custom-calls; the block
structure is what we optimize, not interpret-mode wallclock.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _kernel(n_ref, p_leak_ref, p_dyn_ref, f_clk_ref, d_k_ref, c_comp_ref,
            online_ref, qos_ref, scalars_ref, metrics_ref, d_task_ref):
    """One config-tile step (see module docstring)."""
    n = n_ref[...]                 # [T, K]
    p_leak = p_leak_ref[...]       # [Cb, K]
    p_dyn = p_dyn_ref[...]         # [Cb, K]
    f_clk = f_clk_ref[...]         # [Cb, 1]
    d_k = d_k_ref[...]             # [Cb, K]
    c_comp = c_comp_ref[...]       # [Cb, J]
    online = online_ref[...]       # [1, J]
    qos = qos_ref[...]             # [1, T]
    scalars = scalars_ref[...]     # [1, 4]

    ci_use = scalars[0, 0]
    lifetime = scalars[0, 1]
    beta = scalars[0, 2]
    p_max = scalars[0, 3]

    # §3.3.1 / §3.3.2 — the two contractions, MXU-shaped.
    e_k = (p_leak + p_dyn) / f_clk
    e_task = jax.lax.dot_general(
        e_k, n, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                   # [Cb, T]
    d_task = jax.lax.dot_general(
        d_k, n, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                   # [Cb, T]

    energy = jnp.sum(e_task, axis=1)                    # [Cb]
    delay = jnp.sum(d_task, axis=1)                     # [Cb]

    # §3.3.3 — carbon terms (provisioning contraction [Cb,J]@[J]).
    c_op = ci_use * energy
    c_emb_overall = jnp.sum(c_comp * online, axis=1)    # [Cb]
    c_emb = c_emb_overall * delay / lifetime

    c_total = c_op + c_emb
    tcdp = (c_op + beta * c_emb) * delay

    edp = energy * delay
    cdp = c_emb * delay
    cep = c_emb * energy
    ce2p = cep * energy
    c2ep = c_emb * cep

    qos_ok = jnp.all(d_task <= qos, axis=1)
    avg_power = energy / jnp.maximum(delay, 1e-30)
    feasible = jnp.where(qos_ok & (avg_power <= p_max), 1.0, 0.0)

    metrics_ref[...] = jnp.stack(
        [energy, delay, c_op, c_emb, c_total, tcdp, edp, cdp, cep, ce2p, c2ep, feasible],
        axis=0,
    ).astype(jnp.float32)
    d_task_ref[...] = d_task.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_c",))
def dse_metrics_pallas(n, p_leak, p_dyn, f_clk, d_k, c_comp, online, qos,
                       scalars, *, block_c=128):
    """Blocked Pallas evaluation; same contract as `ref.dse_metrics_ref`.

    ``C`` must be a multiple of ``block_c``.
    """
    t, k = n.shape
    c = p_leak.shape[0]
    j = c_comp.shape[1]
    if c % block_c != 0:
        raise ValueError(f"C={c} not a multiple of block_c={block_c}")
    grid = (c // block_c,)

    online2 = online.reshape(1, j)
    qos2 = qos.reshape(1, t)
    scalars2 = scalars.reshape(1, 4)

    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((t, k), lambda i: (0, 0)),          # n (broadcast)
            pl.BlockSpec((block_c, k), lambda i: (i, 0)),    # p_leak
            pl.BlockSpec((block_c, k), lambda i: (i, 0)),    # p_dyn
            pl.BlockSpec((block_c, 1), lambda i: (i, 0)),    # f_clk
            pl.BlockSpec((block_c, k), lambda i: (i, 0)),    # d_k
            pl.BlockSpec((block_c, j), lambda i: (i, 0)),    # c_comp
            pl.BlockSpec((1, j), lambda i: (0, 0)),          # online
            pl.BlockSpec((1, t), lambda i: (0, 0)),          # qos
            pl.BlockSpec((1, 4), lambda i: (0, 0)),          # scalars
        ],
        out_specs=[
            pl.BlockSpec((ref.NUM_METRICS, block_c), lambda i: (0, i)),
            pl.BlockSpec((block_c, t), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((ref.NUM_METRICS, c), jnp.float32),
            jax.ShapeDtypeStruct((c, t), jnp.float32),
        ],
        interpret=True,
    )(n, p_leak, p_dyn, f_clk, d_k, c_comp, online2, qos2, scalars2)


def vmem_bytes_estimate(block_c, k, t, j):
    """Static VMEM footprint estimate for one grid step, bytes (f32).

    Used by the perf notes in DESIGN.md/EXPERIMENTS.md: the tile must sit
    comfortably under ~16 MiB of VMEM on a real TPU core.
    """
    ins = t * k + 3 * block_c * k + block_c + block_c * j + j + t + 4
    outs = ref.NUM_METRICS * block_c + block_c * t
    scratch = 2 * block_c * t + 8 * block_c  # e_task/d_task + metric temps
    return 4 * (ins + outs + scratch)
