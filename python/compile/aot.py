"""AOT lowering: JAX model → HLO *text* artifacts for the Rust runtime.

HLO text (not serialized HloModuleProto and not `jax.export` bytes) is the
interchange format: jax ≥ 0.5 emits protos with 64-bit instruction ids
that the xla crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`);
the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md and gen_hlo.py.

Usage:
    cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered):
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(c):
    """Lower the model at config-batch size `c` and return HLO text."""
    lowered = jax.jit(model.dse_metrics).lower(*model.example_args(c))
    return to_hlo_text(lowered)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {
        "version": model.ARTIFACT_VERSION,
        "t": model.T_PAD,
        "k": model.K_PAD,
        "j": model.J_PAD,
        "num_metrics": 12,
        "variants": {},
    }
    for c in model.C_VARIANTS:
        text = lower_variant(c)
        name = f"dse_metrics_c{c}.hlo.txt"
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        manifest["variants"][str(c)] = {"file": name, "sha256_16": digest}
        print(f"wrote {path} ({len(text)} chars, sha256/16 {digest})")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
