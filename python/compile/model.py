"""Layer-2 JAX model: the batched DSE metric-evaluation graph.

Wraps the Layer-1 Pallas kernel (`kernels.tcdp_kernel`) into the function
that gets AOT-lowered for the Rust coordinator. The runtime contract
(shapes, input order, output order) is documented in DESIGN.md §2 and
mirrored by `rust/src/runtime/host.rs`; any change here must bump
`ARTIFACT_VERSION` so stale artifacts are rejected.
"""

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.tcdp_kernel import dse_metrics_pallas

#: Bumped whenever the artifact interface changes.
ARTIFACT_VERSION = 1

#: Padded task dimension.
T_PAD = 8
#: Padded kernel dimension.
K_PAD = 32
#: Padded provisioning-component dimension.
J_PAD = 16

#: Config-batch variants AOT-compiled into artifacts/.
C_VARIANTS = (128, 1024)


def dse_metrics(n, p_leak, p_dyn, f_clk, d_k, c_comp, online, qos, scalars):
    """The exported model function (tuple of metrics[12,C], d_task[C,T]).

    All heavy lifting happens in the Pallas kernel; the model layer exists
    so future extensions (e.g. gradient-based design-knob search via
    jax.grad over a relaxed objective) compose at the JAX level.
    """
    metrics, d_task = dse_metrics_pallas(
        n, p_leak, p_dyn, f_clk, d_k, c_comp, online, qos, scalars,
        block_c=128,
    )
    return metrics, d_task


def dse_metrics_reference(n, p_leak, p_dyn, f_clk, d_k, c_comp, online, qos, scalars):
    """Pure-jnp path (no Pallas) — used for differential testing."""
    return ref.dse_metrics_ref(n, p_leak, p_dyn, f_clk, d_k, c_comp, online, qos, scalars)


def example_args(c):
    """ShapeDtypeStructs for AOT lowering at config-batch size `c`."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((T_PAD, K_PAD), f32),   # n
        jax.ShapeDtypeStruct((c, K_PAD), f32),       # p_leak
        jax.ShapeDtypeStruct((c, K_PAD), f32),       # p_dyn
        jax.ShapeDtypeStruct((c, 1), f32),           # f_clk
        jax.ShapeDtypeStruct((c, K_PAD), f32),       # d_k
        jax.ShapeDtypeStruct((c, J_PAD), f32),       # c_comp
        jax.ShapeDtypeStruct((J_PAD,), f32),         # online
        jax.ShapeDtypeStruct((T_PAD,), f32),         # qos
        jax.ShapeDtypeStruct((4,), f32),             # scalars
    )
