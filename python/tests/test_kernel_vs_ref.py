"""L1 correctness: the Pallas kernel must match the pure-jnp oracle.

This is the CORE correctness signal of the build path — the same HLO the
kernel lowers to here is what the Rust runtime executes.
"""

import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile.kernels.ref import dse_metrics_ref, METRIC_ROWS, NUM_METRICS
from compile.kernels.tcdp_kernel import dse_metrics_pallas, vmem_bytes_estimate

from .conftest import make_inputs


def run_both(inputs, block_c=128):
    m_ref, d_ref = dse_metrics_ref(*inputs)
    m_pal, d_pal = dse_metrics_pallas(*inputs, block_c=block_c)
    return (np.asarray(m_ref), np.asarray(d_ref)), (np.asarray(m_pal), np.asarray(d_pal))


class TestKernelMatchesOracle:
    def test_default_shapes(self, inputs):
        (m_ref, d_ref), (m_pal, d_pal) = run_both(inputs)
        assert_allclose(m_pal, m_ref, rtol=1e-5, atol=1e-7)
        assert_allclose(d_pal, d_ref, rtol=1e-5, atol=1e-7)

    @pytest.mark.parametrize("block_c", [16, 32, 64, 128])
    def test_block_size_invariance(self, rng, block_c):
        inputs = make_inputs(rng, c=128)
        (m_ref, _), (m_pal, _) = run_both(inputs, block_c=block_c)
        assert_allclose(m_pal, m_ref, rtol=1e-5, atol=1e-7)

    @pytest.mark.parametrize("c", [128, 256, 1024])
    def test_large_batches(self, rng, c):
        inputs = make_inputs(rng, c=c)
        (m_ref, _), (m_pal, _) = run_both(inputs)
        assert_allclose(m_pal, m_ref, rtol=1e-5, atol=1e-7)

    def test_non_divisible_batch_rejected(self, rng):
        inputs = make_inputs(rng, c=100)
        with pytest.raises(ValueError, match="multiple"):
            dse_metrics_pallas(*inputs, block_c=128)

    def test_zero_padded_rows_are_inert(self, rng):
        # Rows with d_k = 0 and zero power terms must produce zero metrics
        # (f_clk padded to 1.0, not 0, per the runtime contract).
        inputs = list(make_inputs(rng, c=128))
        for idx in (1, 2, 4):  # p_leak, p_dyn, d_k
            inputs[idx][64:] = 0.0
        inputs[3][64:] = 1.0  # f_clk pad
        m_pal, _ = dse_metrics_pallas(*inputs)
        m_pal = np.asarray(m_pal)
        for row, name in enumerate(METRIC_ROWS):
            if name == "feasible":
                continue
            assert np.all(m_pal[row, 64:] == 0.0), f"{name} not inert in padding"


class TestMetricSemantics:
    def test_tcdp_equals_ctotal_times_delay_at_beta_one(self, inputs):
        m, _ = dse_metrics_pallas(*inputs)
        m = np.asarray(m)
        energy, delay = m[0], m[1]
        c_total, tcdp = m[4], m[5]
        assert_allclose(tcdp, c_total * delay, rtol=1e-5)
        assert_allclose(m[6], energy * delay, rtol=1e-5)  # EDP

    def test_metric_identities(self, inputs):
        m, _ = dse_metrics_pallas(*inputs)
        m = np.asarray(m)
        energy, c_emb = m[0], m[3]
        assert_allclose(m[7], c_emb * m[1], rtol=1e-5)        # CDP
        assert_allclose(m[8], c_emb * energy, rtol=1e-5)      # CEP
        assert_allclose(m[9], m[8] * energy, rtol=1e-4)       # CE2P
        assert_allclose(m[10], c_emb * m[8], rtol=1e-4)       # C2EP

    def test_beta_zero_drops_embodied_from_tcdp(self, rng):
        inputs = list(make_inputs(rng))
        inputs[8] = inputs[8].copy()
        inputs[8][2] = 0.0  # beta = 0
        m, _ = dse_metrics_pallas(*inputs)
        m = np.asarray(m)
        assert_allclose(m[5], m[2] * m[1], rtol=1e-5)  # tCDP -> C_op * D

    def test_beta_monotonicity(self, rng):
        base = list(make_inputs(rng))
        tcdps = []
        for beta in (0.0, 0.5, 1.0, 4.0):
            s = base[8].copy()
            s[2] = beta
            m, _ = dse_metrics_pallas(*base[:8], s)
            tcdps.append(np.asarray(m)[5])
        for lo, hi in zip(tcdps, tcdps[1:]):
            assert np.all(lo <= hi + 1e-6)

    def test_qos_constraint_flips_feasibility(self, rng):
        inputs = list(make_inputs(rng))
        m_unconstrained, d_task = dse_metrics_pallas(*inputs)
        d_task = np.asarray(d_task)
        # Bound task 0 at the median per-task delay: roughly half the
        # configs must become infeasible.
        qos = inputs[7].copy()
        qos[0] = np.median(d_task[:, 0])
        inputs[7] = qos
        m_bound, _ = dse_metrics_pallas(*inputs)
        feas0 = np.asarray(m_unconstrained)[11]
        feas1 = np.asarray(m_bound)[11]
        assert feas0.sum() == len(feas0)
        assert 0 < feas1.sum() < len(feas1)
        expected = (d_task[:, 0] <= qos[0]).astype(np.float32)
        assert_allclose(feas1, expected)

    def test_power_constraint(self, rng):
        inputs = list(make_inputs(rng))
        m, _ = dse_metrics_pallas(*inputs)
        m = np.asarray(m)
        avg_power = m[0] / m[1]
        cap = float(np.median(avg_power))
        s = inputs[8].copy()
        s[3] = cap
        m2, _ = dse_metrics_pallas(*inputs[:8], s)
        feas = np.asarray(m2)[11]
        assert_allclose(feas, (avg_power <= cap).astype(np.float32))

    def test_provisioning_mask_scales_embodied(self, rng):
        inputs = list(make_inputs(rng))
        inputs[6] = np.ones_like(inputs[6])
        m_full, _ = dse_metrics_pallas(*inputs)
        half = inputs[6].copy()
        half[: len(half) // 2] = 0.0
        inputs[6] = half
        m_half, _ = dse_metrics_pallas(*inputs)
        c_emb_full = np.asarray(m_full)[3]
        c_emb_half = np.asarray(m_half)[3]
        assert np.all(c_emb_half <= c_emb_full + 1e-9)
        assert c_emb_half.sum() < c_emb_full.sum()


class TestVmemEstimate:
    def test_tile_fits_vmem(self):
        # The c128 tile must sit far below a 16 MiB VMEM budget.
        assert vmem_bytes_estimate(128, 32, 8, 16) < 2 * 1024 * 1024

    def test_estimate_scales_with_block(self):
        small = vmem_bytes_estimate(16, 32, 8, 16)
        big = vmem_bytes_estimate(128, 32, 8, 16)
        assert big > small * 4

    def test_row_count_is_locked(self):
        # Runtime contract: 12 metric rows.
        assert NUM_METRICS == 12
        assert len(METRIC_ROWS) == 12
