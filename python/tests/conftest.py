"""Shared fixtures: realistic random inputs for the DSE evaluation."""

import numpy as np
import pytest


def make_inputs(rng, c=128, t=8, k=32, j=16, ci_use=1.2e-4, lifetime=4.0e6,
                beta=1.0, p_max=np.inf):
    """Random-but-realistic §3.3 inputs (f32)."""
    f32 = np.float32
    n = rng.integers(0, 50, size=(t, k)).astype(f32)
    d_k = rng.uniform(1e-4, 5e-2, size=(c, k)).astype(f32)
    f_clk = rng.uniform(0.5e9, 1.5e9, size=(c, 1)).astype(f32)
    # Power terms scaled so (p_leak+p_dyn)/f_clk lands in the mJ..J range.
    p_leak = (rng.uniform(0.001, 0.05, size=(c, k)) * f_clk).astype(f32)
    p_dyn = (rng.uniform(0.01, 0.5, size=(c, k)) * f_clk).astype(f32)
    c_comp = rng.uniform(10.0, 800.0, size=(c, j)).astype(f32)
    online = (rng.uniform(size=j) < 0.8).astype(f32)
    qos = np.full(t, np.inf, dtype=f32)
    scalars = np.array([ci_use, lifetime, beta, p_max], dtype=f32)
    return n, p_leak, p_dyn, f_clk, d_k, c_comp, online, qos, scalars


@pytest.fixture
def rng():
    return np.random.default_rng(1234)


@pytest.fixture
def inputs(rng):
    return make_inputs(rng)
