"""AOT path: lowering produces loadable HLO text with the locked contract."""

import json
import os

import numpy as np
import pytest

from compile import aot, model


def test_lowered_hlo_is_text_with_entry():
    text = aot.lower_variant(128)
    assert "ENTRY" in text
    assert "f32[12,128]" in text, "metrics output shape missing from HLO"
    assert "f32[128,8]" in text, "d_task output shape missing from HLO"


def test_variant_shapes_differ():
    t128 = aot.lower_variant(128)
    t1024 = aot.lower_variant(1024)
    assert "f32[12,1024]" in t1024
    assert t128 != t1024


def test_example_args_match_contract():
    args = model.example_args(128)
    assert args[0].shape == (model.T_PAD, model.K_PAD)
    assert args[1].shape == (128, model.K_PAD)
    assert args[5].shape == (128, model.J_PAD)
    assert args[8].shape == (4,)
    assert all(a.dtype == np.float32 for a in args)


def test_artifacts_on_disk_match_manifest():
    # `make artifacts` must have produced a coherent manifest; skip if the
    # build step has not run in this checkout.
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest_path = os.path.join(art, "manifest.json")
    if not os.path.exists(manifest_path):
        pytest.skip("artifacts not built")
    with open(manifest_path) as f:
        manifest = json.load(f)
    assert manifest["version"] == model.ARTIFACT_VERSION
    assert manifest["k"] == model.K_PAD
    assert manifest["num_metrics"] == 12
    for c, entry in manifest["variants"].items():
        path = os.path.join(art, entry["file"])
        assert os.path.exists(path), f"missing artifact {path}"
        text = open(path).read()
        assert f"f32[12,{c}]" in text


def test_model_executes_like_kernel():
    # The exported model function is a thin wrapper — verify it returns the
    # kernel's numbers.
    rng = np.random.default_rng(7)
    from .conftest import make_inputs
    inputs = make_inputs(rng)
    m_model, d_model = model.dse_metrics(*inputs)
    m_ref, d_ref = model.dse_metrics_reference(*inputs)
    np.testing.assert_allclose(np.asarray(m_model), np.asarray(m_ref), rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(d_model), np.asarray(d_ref), rtol=1e-5, atol=1e-7)
