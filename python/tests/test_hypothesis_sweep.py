"""Hypothesis sweeps: the Pallas kernel matches the oracle across the
shape/value envelope, not just the fixture shapes."""

import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels.ref import dse_metrics_ref
from compile.kernels.tcdp_kernel import dse_metrics_pallas

F32 = np.float32


def build(seed, c, t, k, j, beta, lifetime_exp, block_c):
    rng = np.random.default_rng(seed)
    n = rng.integers(0, 20, size=(t, k)).astype(F32)
    d_k = rng.uniform(1e-5, 1e-1, size=(c, k)).astype(F32)
    f_clk = rng.uniform(1e8, 2e9, size=(c, 1)).astype(F32)
    p_leak = (rng.uniform(1e-4, 0.1, size=(c, k)) * f_clk).astype(F32)
    p_dyn = (rng.uniform(1e-3, 1.0, size=(c, k)) * f_clk).astype(F32)
    c_comp = rng.uniform(0.0, 1000.0, size=(c, j)).astype(F32)
    online = (rng.uniform(size=j) < 0.7).astype(F32)
    qos = np.where(rng.uniform(size=t) < 0.3,
                   rng.uniform(0.01, 10.0, size=t),
                   np.inf).astype(F32)
    scalars = np.array([1e-4, 10.0 ** lifetime_exp, beta, 50.0], dtype=F32)
    return (n, p_leak, p_dyn, f_clk, d_k, c_comp, online, qos, scalars), block_c


@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    shapes=st.sampled_from([
        # (c, t, k, j, block_c)
        (32, 8, 32, 16, 32),
        (64, 4, 16, 8, 32),
        (128, 8, 32, 16, 128),
        (128, 2, 8, 4, 64),
        (256, 8, 32, 16, 128),
        (64, 1, 1, 1, 16),
    ]),
    beta=st.sampled_from([0.0, 0.25, 1.0, 3.0]),
    lifetime_exp=st.integers(3, 8),
)
def test_kernel_matches_oracle_everywhere(seed, shapes, beta, lifetime_exp):
    c, t, k, j, block_c = shapes
    inputs, block_c = build(seed, c, t, k, j, beta, lifetime_exp, block_c)
    m_ref, d_ref = dse_metrics_ref(*inputs)
    m_pal, d_pal = dse_metrics_pallas(*inputs, block_c=block_c)
    assert_allclose(np.asarray(m_pal), np.asarray(m_ref), rtol=2e-5, atol=1e-6)
    assert_allclose(np.asarray(d_pal), np.asarray(d_ref), rtol=2e-5, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), beta=st.floats(0.0, 10.0))
def test_metric_invariants_hold(seed, beta):
    inputs, block_c = build(seed, 64, 4, 8, 8, beta, 6, 32)
    m, d_task = dse_metrics_pallas(*inputs, block_c=block_c)
    m = np.asarray(m)
    energy, delay, c_op, c_emb, c_total, tcdp = m[0], m[1], m[2], m[3], m[4], m[5]
    # Physical sanity across random draws.
    assert np.all(energy >= 0) and np.all(delay >= 0)
    assert np.all(c_op >= 0) and np.all(c_emb >= 0)
    assert_allclose(c_total, c_op + c_emb, rtol=1e-5)
    # tCDP bounded below by both pure objectives (scaled by beta).
    assert np.all(tcdp >= c_op * delay - 1e-6)
    # d_task rows sum to the delay row.
    assert_allclose(np.asarray(d_task).sum(axis=1), delay, rtol=1e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_permutation_equivariance(seed):
    # Shuffling config rows shuffles outputs identically: no cross-config
    # leakage through the block structure.
    inputs, block_c = build(seed, 64, 4, 8, 8, 1.0, 6, 16)
    perm = np.random.default_rng(seed).permutation(64)
    m1, _ = dse_metrics_pallas(*inputs, block_c=block_c)
    shuffled = list(inputs)
    for idx in (1, 2, 3, 4, 5):
        shuffled[idx] = inputs[idx][perm]
    m2, _ = dse_metrics_pallas(*shuffled, block_c=block_c)
    assert_allclose(np.asarray(m2), np.asarray(m1)[:, perm], rtol=1e-6, atol=1e-8)
